//! Loom model-check suite: exhaustively explores thread interleavings
//! (within a CHESS-style preemption bound, default 2) of the round
//! engine's concurrency protocols and of the one stateful codec.
//!
//! Compiled and run only under the loom cfg:
//!
//! ```text
//! RUSTFLAGS="--cfg loom" cargo test --release --test loom
//! ```
//!
//! Under that cfg the `flocora::sync` shim swaps every Mutex/Condvar/
//! atomic/thread for the instrumented twins in the vendored `loom`
//! crate, so the code being checked here — `BoundedWindow`,
//! `StageRing`, `shard::run_partitioned`,
//! `SparseEfCodec::encode_client` — is the exact code production
//! runs, not a model of it.
//!
//! What a passing run proves, for every schedule explored:
//!
//! * **No lost wakeups** — every test terminates. Model condvars never
//!   wake spuriously, so a forgotten `notify` shows up as a deadlock
//!   here even though a real condvar would usually paper over it.
//! * **Bounded memory** — `peak_buffered() <= window` holds on every
//!   schedule, not just the ones CI happened to run.
//! * **Panic safety** — a participant unwinding mid-protocol (the
//!   sentry path) unblocks every waiter and surfaces as an `Aborted`
//!   drain plus the original panic, never as a hang.
//! * **Determinism under concurrency** — concurrent `encode_client`
//!   calls produce bit-identical payloads and residuals to the serial
//!   reference, regardless of interleaving.
//!
//! Knobs: `LOOM_PREEMPTION_BOUND` (number, or `none` for unbounded
//! DFS) and `LOOM_MAX_ITERATIONS` (schedule cap).
#![cfg(loom)]

use std::panic::{catch_unwind, AssertUnwindSafe};

use flocora::compression::{Codec, SparseEfCodec};
use flocora::coordinator::shard::run_partitioned;
use flocora::coordinator::window::{Aborted, BoundedWindow, StageRing};
use flocora::sync::thread;
use flocora::Error;

// ---------------------------------------------------------------------------
// BoundedWindow: the parallel executor's claim/deposit/drain protocol
// ---------------------------------------------------------------------------

/// Two producers and one drainer over 3 indices, for every window in
/// 1..=3. Termination under every schedule is the no-lost-wakeup
/// proof (window 1 with 2 producers forces the full-window wait on
/// `may_claim`; the in-order drain forces the empty-slot wait on
/// `may_drain`); the peak check is the O(window) memory claim.
#[test]
fn window_claim_drain_terminates_and_bounds_buffering() {
    const N: usize = 3;
    for window in 1..=3usize {
        loom::model(move || {
            let win: BoundedWindow<usize> = BoundedWindow::new(N, window);
            thread::scope(|s| {
                for _ in 0..2 {
                    s.spawn(|| {
                        let _sentry = win.sentry();
                        while let Some(i) = win.claim() {
                            if !win.deposit(i, 10 * i) {
                                break;
                            }
                        }
                    });
                }
                let _sentry = win.sentry();
                for i in 0..N {
                    assert_eq!(win.drain(i), Ok(10 * i), "window={window}");
                }
            });
            let peak = win.peak_buffered();
            assert!(
                (1..=window).contains(&peak),
                "peak_buffered {peak} escaped window {window}"
            );
        });
    }
}

/// A producer panics inside its work item. The sentry must flag the
/// abort and wake the drainer on every schedule — the drainer sees
/// `Err(Aborted)` for both indices (never a value, never a hang), and
/// the scope join re-raises the producer's panic.
#[test]
fn window_sentry_turns_a_producer_panic_into_aborted_drains() {
    loom::model(|| {
        let win: BoundedWindow<usize> = BoundedWindow::new(2, 2);
        let mut results = Vec::new();
        let caught = catch_unwind(AssertUnwindSafe(|| {
            thread::scope(|s| {
                s.spawn(|| {
                    let _sentry = win.sentry();
                    let _ = win.claim();
                    panic!("client work exploded");
                });
                let _sentry = win.sentry();
                for i in 0..2 {
                    results.push(win.drain(i));
                }
            });
        }));
        assert!(caught.is_err(), "scope must re-raise the worker panic");
        assert_eq!(results, [Err(Aborted), Err(Aborted)]);
    });
}

/// `abort` must wake a producer that is parked on a full window —
/// with window 1 and index 0 never drained, the spawned claim can
/// only return via the abort path. A missing `may_claim` notify in
/// `abort` shows up here as a deadlock.
#[test]
fn window_abort_unblocks_a_parked_claimer() {
    loom::model(|| {
        let win: BoundedWindow<u8> = BoundedWindow::new(3, 1);
        thread::scope(|s| {
            assert_eq!(win.claim(), Some(0));
            s.spawn(|| {
                assert_eq!(win.claim(), None, "abort must free this claim");
            });
            win.abort();
        });
        assert_eq!(win.drain(0), Err(Aborted));
    });
}

// ---------------------------------------------------------------------------
// StageRing: the pipelined executor's staged hand-off protocol
// ---------------------------------------------------------------------------

/// Mirrors the executor's `PipeSlot` shape: claim fills `Fetched`, a
/// second stage steals it by predicate and advances it to `Done`, the
/// drainer extracts in index order.
#[derive(Default, Debug, PartialEq)]
enum Slot {
    #[default]
    Empty,
    Fetched(usize),
    Training,
    Done(usize),
}

fn take_done(s: &mut Slot) -> Option<usize> {
    match std::mem::take(s) {
        Slot::Done(v) => Some(v),
        other => {
            *s = other;
            None
        }
    }
}

/// A 3-stage pipeline (fetch thread, train thread, draining root) over
/// 2 indices. Every schedule must deliver both results, in order, with
/// the stage hand-offs riding the single broadcast condvar — a lost
/// broadcast anywhere (put, drain) deadlocks some schedule.
#[test]
fn ring_three_stage_pipeline_delivers_in_order() {
    loom::model(|| {
        const N: usize = 2;
        let ring: StageRing<Slot> = StageRing::new(N, 2);
        thread::scope(|s| {
            s.spawn(|| {
                let _sentry = ring.sentry();
                while let Some(i) = ring.claim() {
                    if !ring.put(i, Slot::Fetched(10 + i), false) {
                        break;
                    }
                }
            });
            s.spawn(|| {
                let _sentry = ring.sentry();
                while let Some((i, v)) = ring.take_matching(|s| match s {
                    Slot::Fetched(v) => {
                        let v = *v;
                        *s = Slot::Training;
                        Some(v)
                    }
                    _ => None,
                }) {
                    if !ring.put(i, Slot::Done(2 * v), true) {
                        break;
                    }
                }
            });
            let _sentry = ring.sentry();
            for i in 0..N {
                assert_eq!(ring.drain(i, take_done), Ok(2 * (10 + i)));
            }
        });
        let peak = ring.peak_buffered();
        assert!((1..=2).contains(&peak), "peak_buffered {peak}");
    });
}

/// A stage panics mid-pipeline: the ring's sentry must abort, the
/// drainer must see `Err(Aborted)` on every schedule, and the panic
/// must come back out of the scope.
#[test]
fn ring_sentry_turns_a_stage_panic_into_aborted_drains() {
    loom::model(|| {
        let ring: StageRing<Slot> = StageRing::new(1, 1);
        let mut got = None;
        let caught = catch_unwind(AssertUnwindSafe(|| {
            thread::scope(|s| {
                s.spawn(|| {
                    let _sentry = ring.sentry();
                    let _ = ring.claim();
                    panic!("train step exploded");
                });
                let _sentry = ring.sentry();
                got = Some(ring.drain(0, take_done));
            });
        }));
        assert!(caught.is_err(), "scope must re-raise the stage panic");
        assert_eq!(got, Some(Err(Aborted)));
    });
}

// ---------------------------------------------------------------------------
// shard::run_partitioned: the sharded coordinator's claim/merge handshake
// ---------------------------------------------------------------------------

/// Two shards on two workers: whatever the schedule, the coordinator
/// drains both partials and returns them in canonical shard order —
/// the order the cross-shard merge depends on for bit-identity.
/// Termination everywhere is the no-lost-wakeup proof for the
/// shard-sized window (`window = shards`, so claims never park; only
/// the in-order drain waits).
#[test]
fn shard_handshake_drains_partials_in_canonical_order() {
    loom::model(|| {
        let got = run_partitioned(2, 2, |j| Ok(100 + j)).unwrap();
        assert_eq!(got, vec![100, 101]);
    });
}

/// A failing shard must abort the round on every schedule: the
/// coordinator sees the shard's `Err` at its canonical drain slot
/// (never a hang, never a partial merge) and the other worker's claim
/// loop winds down through the abort path.
#[test]
fn shard_handshake_propagates_a_shard_error() {
    loom::model(|| {
        let err = run_partitioned::<usize>(2, 2, |j| {
            if j == 1 {
                Err(Error::invalid("shard 1 failed"))
            } else {
                Ok(j)
            }
        })
        .unwrap_err();
        assert!(err.to_string().contains("shard 1 failed"), "{err}");
    });
}

/// A shard *panicking* (a bug — shard work reports failure via
/// `Result`) must still never hang the coordinator: the sentry flags
/// the abort, the drain surfaces it, and the scope join re-raises the
/// panic out of `run_partitioned`.
#[test]
fn shard_handshake_survives_a_panicking_shard() {
    loom::model(|| {
        let caught = catch_unwind(AssertUnwindSafe(|| {
            run_partitioned::<usize>(2, 2, |j| {
                if j == 1 {
                    panic!("shard work exploded");
                }
                Ok(j)
            })
        }));
        assert!(caught.is_err(), "the shard panic must re-raise");
    });
}

// ---------------------------------------------------------------------------
// SparseEfCodec: concurrent stateful uploads
// ---------------------------------------------------------------------------

/// Two clients upload concurrently through one `SparseEfCodec`. The
/// residual map is shared mutable state behind the shim's mutex; the
/// claim is that *any* interleaving of the two uploads produces
/// payloads and residual accumulators bit-identical to running them
/// serially — client streams must not be able to observe scheduling.
#[test]
fn sparse_ef_concurrent_uploads_match_the_serial_reference() {
    const V1: [f32; 4] = [0.5, -2.0, 0.25, 1.0];
    const V2: [f32; 4] = [-1.5, 0.125, 3.0, -0.75];

    let expected = {
        let codec = SparseEfCodec::new(0.5);
        let p1 = codec.encode_client(1, &V1, &[]).unwrap().payload;
        let p2 = codec.encode_client(2, &V2, &[]).unwrap().payload;
        (p1, p2, codec.residual(1).unwrap(), codec.residual(2).unwrap())
    };

    loom::model(move || {
        let codec = SparseEfCodec::new(0.5);
        let (p1, p2) = thread::scope(|s| {
            let h1 = s
                .spawn(|| codec.encode_client(1, &V1, &[]).unwrap().payload);
            let h2 = s
                .spawn(|| codec.encode_client(2, &V2, &[]).unwrap().payload);
            (h1.join().unwrap(), h2.join().unwrap())
        });
        assert_eq!(p1, expected.0, "client 1 payload depends on schedule");
        assert_eq!(p2, expected.1, "client 2 payload depends on schedule");
        assert_eq!(codec.residual(1).unwrap(), expected.2);
        assert_eq!(codec.residual(2).unwrap(), expected.3);
    });
}
