//! Round-engine parity tests: the serial and windowed-parallel engines
//! must be observationally identical — bit-for-bit — for any fixed seed
//! and any out-of-order window. This is the determinism contract of
//! `coordinator::executor` (per-client RNG from `(seed, round, cid)`,
//! results streamed into the sink in sampling order), plus the
//! streaming-memory contract (peak buffered results ≤ window) and the
//! hetero-rank plan's parity against the reference round loop that
//! `examples/hetero_ranks.rs` used to hand-roll.
//!
//! Requires `make artifacts`, like tests/integration.rs.

use flocora::compression::{Codec, Fp32Codec};
use flocora::config::{presets, FlConfig};
use flocora::coordinator::executor::{ClientResult, Downloads,
                                     ParallelExecutor, RoundContext};
use flocora::coordinator::hetero::project_ranks;
use flocora::coordinator::sink::RoundSink;
use flocora::coordinator::{ClientExecutor, ExecutorKind, FedAvg,
                           LocalTrainer, SamplerKind, Simulation,
                           UniformSampler};
use flocora::data::lda_partition;
use flocora::metrics::Recorder;
use flocora::runtime::Engine;
use flocora::util::rng::Rng;

fn engine() -> std::rc::Rc<Engine> {
    thread_local! {
        static ENGINE: std::rc::Rc<Engine> = std::rc::Rc::new(
            Engine::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
                .expect("run `make artifacts` first"));
    }
    ENGINE.with(|e| e.clone())
}

fn base_cfg() -> FlConfig {
    FlConfig {
        tag: "micro8_lora_fc_r4".into(),
        num_clients: 8,
        clients_per_round: 4,
        rounds: 3,
        local_epochs: 1,
        samples_per_client: 16,
        test_samples: 40,
        seed: 21,
        ..FlConfig::default()
    }
}

fn hetero_cfg() -> FlConfig {
    FlConfig {
        tag: "micro8_lora_fc_r8".into(),
        num_clients: 12,
        clients_per_round: 4,
        rounds: 3,
        local_epochs: 1,
        lora_alpha: 64.0,
        samples_per_client: 16,
        test_samples: 40,
        seed: 33,
        hetero_ranks: vec![2, 4, 8],
        ..FlConfig::default()
    }
}

/// Full observable state of one finished run.
struct Observed {
    global: Vec<f32>,
    final_acc: f64,
    final_train_loss: f64,
    total_bytes: u64,
    up_bytes: u64,
    down_bytes: u64,
    per_round: Vec<u64>,
    dropped: u64,
    cancelled: u64,
    tier_bytes: Vec<u64>,
    sim_net_parallel_s: f64,
    sim_net_pipelined_s: f64,
    transfer_wait_s: f64,
    sim_client_p50_s: f64,
    sim_client_max_s: f64,
}

fn run(cfg: FlConfig) -> Observed {
    let e = engine();
    let mut sim = Simulation::new(&e, cfg).unwrap();
    let mut rec = Recorder::new("exec");
    let summary = sim.run(&mut rec).unwrap();
    Observed {
        global: sim.global.clone(),
        final_acc: summary.final_acc,
        final_train_loss: summary.final_train_loss,
        total_bytes: summary.total_bytes,
        up_bytes: sim.ledger.up_bytes,
        down_bytes: sim.ledger.down_bytes,
        per_round: sim.ledger.per_round.clone(),
        dropped: sim.dropped_clients,
        cancelled: sim.cancelled_clients,
        tier_bytes: sim.tier_bytes().to_vec(),
        sim_net_parallel_s: summary.sim_net_parallel_s,
        sim_net_pipelined_s: summary.sim_net_pipelined_s,
        transfer_wait_s: summary.transfer_wait_s,
        sim_client_p50_s: summary.sim_client_p50_s,
        sim_client_max_s: summary.sim_client_max_s,
    }
}

fn with_executor(mut cfg: FlConfig, kind: ExecutorKind, threads: usize)
                 -> FlConfig {
    cfg.executor = kind;
    cfg.threads = threads;
    cfg
}

fn with_window(mut cfg: FlConfig, window: usize) -> FlConfig {
    cfg.executor = ExecutorKind::Parallel;
    cfg.window = window;
    cfg
}

fn with_shards(mut cfg: FlConfig, shards: usize) -> FlConfig {
    cfg.shards = shards;
    cfg
}

fn assert_identical(a: &Observed, b: &Observed, what: &str) {
    // Bit-identity everywhere: f32 params compared exactly, f64 metrics
    // compared exactly. Any executor-order dependence shows up here.
    assert_eq!(a.global, b.global, "{what}: global vector diverged");
    assert_eq!(a.final_acc, b.final_acc, "{what}: final_acc");
    assert_eq!(a.total_bytes, b.total_bytes, "{what}: total_bytes");
    assert_eq!(a.up_bytes, b.up_bytes, "{what}: up_bytes");
    assert_eq!(a.down_bytes, b.down_bytes, "{what}: down_bytes");
    assert_eq!(a.per_round, b.per_round, "{what}: per-round ledger");
    assert_eq!(a.dropped, b.dropped, "{what}: dropout count");
    assert_eq!(a.cancelled, b.cancelled, "{what}: cancelled count");
    assert_eq!(a.tier_bytes, b.tier_bytes, "{what}: per-tier bytes");
    assert_eq!(a.sim_net_parallel_s, b.sim_net_parallel_s,
               "{what}: simulated net time");
    assert_eq!(a.sim_net_pipelined_s, b.sim_net_pipelined_s,
               "{what}: simulated pipelined time");
    assert_eq!(a.transfer_wait_s, b.transfer_wait_s,
               "{what}: transfer wait");
    assert_eq!(a.sim_client_p50_s, b.sim_client_p50_s,
               "{what}: client p50 time");
    assert_eq!(a.sim_client_max_s, b.sim_client_max_s,
               "{what}: client max time");
    // NaN-tolerant equality for the train loss (a fully-dropped final
    // round reports NaN under both executors).
    assert!(
        a.final_train_loss == b.final_train_loss
            || (a.final_train_loss.is_nan() && b.final_train_loss.is_nan()),
        "{what}: final_train_loss {} vs {}",
        a.final_train_loss,
        b.final_train_loss
    );
}

#[test]
fn parallel_is_bit_identical_to_serial() {
    let serial = run(with_executor(base_cfg(), ExecutorKind::Serial, 0));
    let parallel = run(with_executor(base_cfg(), ExecutorKind::Parallel, 0));
    assert_identical(&serial, &parallel, "clean run");
}

#[test]
fn thread_count_does_not_change_results() {
    let one = run(with_executor(base_cfg(), ExecutorKind::Parallel, 1));
    let two = run(with_executor(base_cfg(), ExecutorKind::Parallel, 2));
    let many = run(with_executor(base_cfg(), ExecutorKind::Parallel, 7));
    assert_identical(&one, &two, "1 vs 2 threads");
    assert_identical(&one, &many, "1 vs 7 threads");
}

#[test]
fn window_size_does_not_change_results() {
    // The streaming merge is bit-identical to the serial reference at
    // any out-of-order window — window 1 (fully in-order production),
    // a tight window, and one wider than the round.
    let serial = run(with_executor(base_cfg(), ExecutorKind::Serial, 0));
    let w1 = run(with_window(with_executor(base_cfg(),
                                           ExecutorKind::Parallel, 4), 1));
    let w2 = run(with_window(with_executor(base_cfg(),
                                           ExecutorKind::Parallel, 4), 2));
    let wide = run(with_window(with_executor(base_cfg(),
                                             ExecutorKind::Parallel, 4), 64));
    assert_identical(&serial, &w1, "serial vs window=1");
    assert_identical(&serial, &w2, "serial vs window=2");
    assert_identical(&serial, &wide, "serial vs window=64");
}

#[test]
fn window_size_identical_under_dropout() {
    let mut cfg = base_cfg();
    cfg.dropout = 0.4;
    cfg.rounds = 4;
    let serial = run(with_executor(cfg.clone(), ExecutorKind::Serial, 0));
    let w1 = run(with_window(cfg.clone(), 1));
    let w3 = run(with_window(cfg, 3));
    assert_identical(&serial, &w1, "dropout, window=1");
    assert_identical(&serial, &w3, "dropout, window=3");
}

#[test]
fn dropout_counting_matches_across_executors() {
    let mut cfg = base_cfg();
    cfg.dropout = 0.5;
    cfg.rounds = 5;
    let serial = run(with_executor(cfg.clone(), ExecutorKind::Serial, 0));
    let parallel = run(with_executor(cfg, ExecutorKind::Parallel, 0));
    assert!(serial.dropped > 0, "injection never fired at dropout=0.5");
    assert_identical(&serial, &parallel, "dropout run");
}

#[test]
fn zero_survivor_rounds_behave_identically() {
    // Dropout so high that whole rounds are lost with near-certainty:
    // 20 Bernoulli(0.97) survival failures per run. Both executors must
    // count the same drops, move the same bytes (downloads still
    // happen), and leave the global vector identical.
    let mut cfg = base_cfg();
    cfg.dropout = 0.97;
    cfg.rounds = 5;
    let serial = run(with_executor(cfg.clone(), ExecutorKind::Serial, 0));
    let parallel = run(with_executor(cfg, ExecutorKind::Parallel, 0));
    assert_identical(&serial, &parallel, "zero-survivor run");
    // With these odds at least one round lost every client; the run
    // still finishes and the ledger still has one bucket per round.
    assert_eq!(serial.per_round.len(), 5);
    assert!(serial.dropped >= 15, "only {} drops at 0.97", serial.dropped);
}

#[test]
fn executors_identical_under_quantized_codec() {
    // The codec round trip happens inside the per-client work; make
    // sure a lossy wire format stays order-independent too.
    let mut cfg = base_cfg();
    cfg.codec = flocora::compression::CodecKind::Affine(8);
    let serial = run(with_executor(cfg.clone(), ExecutorKind::Serial, 0));
    let parallel = run(with_executor(cfg, ExecutorKind::Parallel, 3));
    assert_identical(&serial, &parallel, "q8 run");
}

/// In-order assertion sink that dawdles on every push, giving the
/// workers every opportunity to run ahead of the merge — without the
/// window gate they would buffer nearly the whole round here.
struct SlowCountingSink {
    next: usize,
    clients: Vec<usize>,
}

impl RoundSink for SlowCountingSink {
    fn push(&mut self, index: usize, result: ClientResult)
            -> flocora::Result<()> {
        assert_eq!(index, self.next, "sink saw an out-of-order push");
        assert_eq!(result.cid, self.clients[index],
                   "slot {index} carries the wrong client");
        std::thread::sleep(std::time::Duration::from_millis(25));
        self.next += 1;
        Ok(())
    }
}

#[test]
fn peak_buffered_results_never_exceed_window() {
    let e = engine();
    let cfg = base_cfg();
    let session = e.session(&cfg.tag).unwrap();
    let spec = session.spec.clone();
    let federation = lda_partition(
        cfg.num_clients,
        cfg.samples_per_client,
        spec.num_classes,
        spec.image_size,
        cfg.lda_alpha,
        cfg.seed,
    );
    let (global, frozen) = session.init(cfg.seed).unwrap();
    let codec = Fp32Codec;
    let down_msg = codec.encode(&global, &spec.trainable_segments).unwrap();
    let ctx = RoundContext {
        session: &session,
        codec: &codec,
        federation: &federation,
        frozen: &frozen,
        downloads: Downloads::Homogeneous(&down_msg),
        trainer: LocalTrainer {
            local_epochs: 1,
            lr: cfg.lr,
            lora_scale: cfg.lora_scale(spec.rank),
        },
        cfg: &cfg,
        round: 0,
        plan: None,
        cancelled: &[],
    };
    let clients: Vec<usize> = (0..cfg.num_clients).collect();

    for window in [1usize, 2, 3] {
        let exec = ParallelExecutor::new(4).with_window(window);
        let mut sink =
            SlowCountingSink { next: 0, clients: clients.clone() };
        exec.execute(&ctx, &clients, &mut sink).unwrap();
        assert_eq!(sink.next, clients.len(), "sink missed pushes");
        let peak = exec.peak_buffered();
        assert!(peak >= 1, "window {window}: nothing ever buffered?");
        assert!(
            peak <= window,
            "window {window}: {peak} results buffered simultaneously"
        );
    }
}

#[test]
fn shard_counts_are_bit_identical_on_the_real_backend() {
    // The sharded coordinator against the PJRT artifacts: every shard
    // count in {1, 2, 3, 7} replays the unsharded serial round
    // bit-for-bit, whichever executor runs inside the shards.
    let baseline = run(with_executor(base_cfg(), ExecutorKind::Serial, 0));
    for shards in [1usize, 2, 3, 7] {
        let serial = run(with_shards(
            with_executor(base_cfg(), ExecutorKind::Serial, 0), shards));
        let parallel = run(with_shards(
            with_executor(base_cfg(), ExecutorKind::Parallel, 3), shards));
        let windowed =
            run(with_shards(with_window(base_cfg(), 2), shards));
        assert_identical(&baseline, &serial,
                         &format!("shards={shards}: serial"));
        assert_identical(&baseline, &parallel,
                         &format!("shards={shards}: parallel"));
        assert_identical(&baseline, &windowed,
                         &format!("shards={shards}: window=2"));
    }
}

#[test]
fn shard_identity_survives_dropout_stragglers_and_hetero() {
    // The ragged regimes on the real backend: dropout skips aggregator
    // folds mid-block, the straggler preset cancels oversampled
    // clients, hetero tiers project ranks — the shard partition must
    // stay invisible through all of them.
    let mut dropout = base_cfg();
    dropout.dropout = 0.4;
    dropout.rounds = 4;
    for (what, cfg) in [("dropout", dropout),
                        ("straggler", straggler_cfg()),
                        ("hetero", hetero_cfg())] {
        let one = run(with_executor(cfg.clone(), ExecutorKind::Serial, 0));
        for shards in [2usize, 3, 7] {
            let n = run(with_shards(
                with_executor(cfg.clone(), ExecutorKind::Parallel, 3),
                shards,
            ));
            assert_identical(&one, &n, &format!("{what}: shards={shards}"));
        }
    }
}

#[test]
fn hetero_plan_is_bit_identical_across_executors() {
    let serial = run(with_executor(hetero_cfg(), ExecutorKind::Serial, 0));
    let parallel =
        run(with_executor(hetero_cfg(), ExecutorKind::Parallel, 3));
    let windowed = run(with_window(hetero_cfg(), 2));
    assert_identical(&serial, &parallel, "hetero serial vs parallel");
    assert_identical(&serial, &windowed, "hetero serial vs window=2");
    // Tier accounting: three tiers, traffic everywhere, and the r=2
    // tier's messages are strictly smaller than the r=8 tier's — so
    // equal sampling would give it fewer bytes; just pin shape + sum.
    assert_eq!(serial.tier_bytes.len(), 3);
    assert_eq!(
        serial.tier_bytes.iter().sum::<u64>(),
        serial.total_bytes,
        "tier bytes must partition total traffic"
    );
}

#[test]
fn hetero_engine_matches_reference_loop() {
    // The semantics `examples/hetero_ranks.rs` used to hand-roll, under
    // the engine's sampling/RNG contract: per-tier down-projection +
    // codec round trip, tier-local training at alpha/r_tier, codec'd
    // upload, up-projection, FedAvg in sampling order. The engine's
    // hetero plan must reproduce it bit-for-bit.
    let e = engine();
    let cfg = hetero_cfg();

    let mut sim = Simulation::new(&e, cfg.clone()).unwrap();
    for _ in 0..cfg.rounds {
        sim.round().unwrap();
    }

    let server = e.session(&cfg.tag).unwrap();
    let tiers = [
        e.session("micro8_lora_fc_r2").unwrap(),
        e.session("micro8_lora_fc_r4").unwrap(),
        e.session("micro8_lora_fc_r8").unwrap(),
    ];
    let server_segs = &server.spec.trainable_segments;
    let federation = lda_partition(
        cfg.num_clients,
        cfg.samples_per_client,
        server.spec.num_classes,
        server.spec.image_size,
        cfg.lda_alpha,
        cfg.seed,
    );
    let (mut global, frozen) = server.init(cfg.seed).unwrap();
    let mut sampler = UniformSampler::new(cfg.num_clients, cfg.seed);
    let codec = Fp32Codec;

    for round in 0..cfg.rounds {
        let downs: Vec<Vec<f32>> = tiers
            .iter()
            .map(|sess| {
                let segs = &sess.spec.trainable_segments;
                let proj = project_ranks(&global, server_segs, segs).unwrap();
                let msg = codec.encode(&proj, segs).unwrap();
                codec.decode(&msg, segs).unwrap()
            })
            .collect();
        let ids = sampler.sample(cfg.clients_per_round);
        let lr = cfg.lr * cfg.lr_decay.powi(round as i32);
        let mut agg = FedAvg::new(global.len());
        for &cid in &ids {
            let t = cid % tiers.len();
            let sess = &tiers[t];
            let segs = &sess.spec.trainable_segments;
            let trainer = LocalTrainer {
                local_epochs: cfg.local_epochs,
                lr,
                lora_scale: cfg.lora_alpha / sess.spec.rank as f32,
            };
            let mut crng =
                Rng::for_client(cfg.seed, round as u64, cid as u64);
            let out = trainer
                .run(sess, &federation.clients[cid], &frozen,
                     downs[t].clone(), &mut crng)
                .unwrap();
            let msg = codec.encode(&out.params, segs).unwrap();
            let up = codec.decode(&msg, segs).unwrap();
            let proj = project_ranks(&up, segs, server_segs).unwrap();
            agg.add(&proj, out.samples as f64).unwrap();
        }
        global = agg.finish().unwrap();
    }

    assert_eq!(sim.global, global,
               "hetero engine diverged from the reference loop");
}

/// The straggler regime at test size: tiered profiles, oversampled
/// sampling, short schedule.
fn straggler_cfg() -> FlConfig {
    let mut cfg = presets::by_name("straggler_micro").unwrap();
    cfg.rounds = 8;
    cfg.local_epochs = 1;
    cfg.samples_per_client = 16;
    cfg.test_samples = 40;
    cfg.seed = 21;
    cfg
}

#[test]
fn latency_biased_is_bit_identical_across_executors() {
    let mut cfg = straggler_cfg();
    cfg.sampler = SamplerKind::LatencyBiased;
    let serial = run(with_executor(cfg.clone(), ExecutorKind::Serial, 0));
    let parallel = run(with_executor(cfg.clone(), ExecutorKind::Parallel, 3));
    let windowed = run(with_window(cfg, 2));
    assert_identical(&serial, &parallel, "latency_biased serial vs parallel");
    assert_identical(&serial, &windowed, "latency_biased serial vs window=2");
    assert_eq!(serial.cancelled, 0, "latency_biased never cancels");
}

#[test]
fn oversample_is_bit_identical_across_executors() {
    // Cancellation is planned on the coordinator from expected round
    // trips, so the cut — and everything downstream of it — must be
    // the same whichever executor ran the round.
    let cfg = straggler_cfg();
    let serial = run(with_executor(cfg.clone(), ExecutorKind::Serial, 0));
    let parallel = run(with_executor(cfg.clone(), ExecutorKind::Parallel, 3));
    let windowed = run(with_window(cfg.clone(), 2));
    assert_identical(&serial, &parallel, "oversample serial vs parallel");
    assert_identical(&serial, &windowed, "oversample serial vs window=2");
    // 6 drawn, 4 accepted, no dropout: 2 cancelled every round.
    assert_eq!(serial.cancelled, 2 * cfg.rounds as u64);

    // With dropout the cancellation plan must keep replaying the same
    // per-client coin the executors draw.
    let mut drop_cfg = straggler_cfg();
    drop_cfg.dropout = 0.3;
    let s = run(with_executor(drop_cfg.clone(), ExecutorKind::Serial, 0));
    let p = run(with_executor(drop_cfg, ExecutorKind::Parallel, 0));
    assert!(s.dropped > 0, "injection never fired at dropout=0.3");
    assert_identical(&s, &p, "oversample+dropout serial vs parallel");
}

#[test]
fn pipelined_overlap_is_bit_identical_under_stragglers() {
    // The staged `overlap = transfer` engine against the serial
    // reference, in the regime with every moving part at once: tiered
    // link/compute profiles, oversampled sampling, planned
    // cancellations. Only simulated-time *modelling* may differ — and
    // it is computed identically in both modes, so the whole Observed
    // struct must match bit-for-bit. The pipelined estimate itself
    // must strictly beat the no-overlap concurrent estimate here
    // (every accepted client has three non-zero stages to overlap).
    let mut cfg = straggler_cfg();
    cfg.overlap = flocora::transport::OverlapKind::Transfer;
    let serial_none = run(with_executor(straggler_cfg(),
                                        ExecutorKind::Serial, 0));
    let pipelined = run(with_executor(cfg.clone(),
                                      ExecutorKind::Parallel, 3));
    let pipelined_w2 = run(with_window(cfg, 2));
    assert!(serial_none.cancelled > 0, "no cancellations exercised");
    assert_identical(&serial_none, &pipelined,
                     "serial/none vs pipelined/transfer");
    assert_identical(&serial_none, &pipelined_w2,
                     "serial/none vs pipelined/transfer w=2");
    assert!(
        pipelined.sim_net_pipelined_s < pipelined.sim_net_parallel_s,
        "pipelined {:.4}s did not beat parallel {:.4}s",
        pipelined.sim_net_pipelined_s,
        pipelined.sim_net_parallel_s
    );
    assert!(pipelined.transfer_wait_s > 0.0);
}

#[test]
fn oversample_beta_zero_is_bit_identical_to_uniform() {
    // β = 0 shares the uniform sampler's RNG stream and never
    // over-draws, so the whole run — sampling, merge order, ledger,
    // global vector — replays `sampler = uniform` exactly.
    let mut uni = straggler_cfg();
    uni.sampler = SamplerKind::Uniform;
    uni.oversample_beta = 0.0;
    let mut over = straggler_cfg();
    over.oversample_beta = 0.0;
    let a = run(uni);
    let b = run(over);
    assert_identical(&a, &b, "uniform vs oversample β=0");
    assert_eq!(b.cancelled, 0);
}

#[test]
fn oversample_strictly_reduces_straggler_time() {
    // The acceptance bar for the straggler work: on the tiered-profile
    // preset, cancelling expected stragglers (β > 0) must strictly
    // beat uniform sampling on simulated concurrent wire time, while
    // moving *more* download bytes (the oversampled pulls are the
    // price) — and the accuracy pipeline still runs to completion.
    let mut uni = straggler_cfg();
    uni.sampler = SamplerKind::Uniform;
    let over = straggler_cfg();
    let u = run(uni);
    let o = run(over);
    assert!(o.cancelled > 0, "oversampling never cancelled anyone");
    assert!(
        o.sim_net_parallel_s < u.sim_net_parallel_s,
        "oversample_k {:.3}s did not beat uniform {:.3}s",
        o.sim_net_parallel_s,
        u.sim_net_parallel_s
    );
    assert!(o.down_bytes > u.down_bytes,
            "oversampled rounds must pull more downloads");
    // The straggler stats see the same picture: the slowest client the
    // server actually waited on shrank too (cancelled stragglers are
    // excluded from the max by construction).
    assert!(o.sim_client_max_s <= u.sim_client_max_s,
            "cancellation cannot worsen the waited-on straggler");
}
