//! Executor-parity tests: the serial and parallel round engines must be
//! observationally identical — bit-for-bit — for any fixed seed. This is
//! the determinism contract of `coordinator::executor` (per-client RNG
//! from `(seed, round, cid)`, results merged in sampling order).
//!
//! Requires `make artifacts`, like tests/integration.rs.

use flocora::config::FlConfig;
use flocora::coordinator::{ExecutorKind, Simulation};
use flocora::metrics::Recorder;
use flocora::runtime::Engine;

fn engine() -> std::rc::Rc<Engine> {
    thread_local! {
        static ENGINE: std::rc::Rc<Engine> = std::rc::Rc::new(
            Engine::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
                .expect("run `make artifacts` first"));
    }
    ENGINE.with(|e| e.clone())
}

fn base_cfg() -> FlConfig {
    FlConfig {
        tag: "micro8_lora_fc_r4".into(),
        num_clients: 8,
        clients_per_round: 4,
        rounds: 3,
        local_epochs: 1,
        samples_per_client: 16,
        test_samples: 40,
        seed: 21,
        ..FlConfig::default()
    }
}

/// Full observable state of one finished run.
struct Observed {
    global: Vec<f32>,
    final_acc: f64,
    final_train_loss: f64,
    total_bytes: u64,
    up_bytes: u64,
    down_bytes: u64,
    per_round: Vec<u64>,
    dropped: u64,
    sim_net_parallel_s: f64,
}

fn run(cfg: FlConfig) -> Observed {
    let e = engine();
    let mut sim = Simulation::new(&e, cfg).unwrap();
    let mut rec = Recorder::new("exec");
    let summary = sim.run(&mut rec).unwrap();
    Observed {
        global: sim.global.clone(),
        final_acc: summary.final_acc,
        final_train_loss: summary.final_train_loss,
        total_bytes: summary.total_bytes,
        up_bytes: sim.ledger.up_bytes,
        down_bytes: sim.ledger.down_bytes,
        per_round: sim.ledger.per_round.clone(),
        dropped: sim.dropped_clients,
        sim_net_parallel_s: summary.sim_net_parallel_s,
    }
}

fn with_executor(mut cfg: FlConfig, kind: ExecutorKind, threads: usize)
                 -> FlConfig {
    cfg.executor = kind;
    cfg.threads = threads;
    cfg
}

fn assert_identical(a: &Observed, b: &Observed, what: &str) {
    // Bit-identity everywhere: f32 params compared exactly, f64 metrics
    // compared exactly. Any executor-order dependence shows up here.
    assert_eq!(a.global, b.global, "{what}: global vector diverged");
    assert_eq!(a.final_acc, b.final_acc, "{what}: final_acc");
    assert_eq!(a.total_bytes, b.total_bytes, "{what}: total_bytes");
    assert_eq!(a.up_bytes, b.up_bytes, "{what}: up_bytes");
    assert_eq!(a.down_bytes, b.down_bytes, "{what}: down_bytes");
    assert_eq!(a.per_round, b.per_round, "{what}: per-round ledger");
    assert_eq!(a.dropped, b.dropped, "{what}: dropout count");
    assert_eq!(a.sim_net_parallel_s, b.sim_net_parallel_s,
               "{what}: simulated net time");
    // NaN-tolerant equality for the train loss (a fully-dropped final
    // round reports NaN under both executors).
    assert!(
        a.final_train_loss == b.final_train_loss
            || (a.final_train_loss.is_nan() && b.final_train_loss.is_nan()),
        "{what}: final_train_loss {} vs {}",
        a.final_train_loss,
        b.final_train_loss
    );
}

#[test]
fn parallel_is_bit_identical_to_serial() {
    let serial = run(with_executor(base_cfg(), ExecutorKind::Serial, 0));
    let parallel = run(with_executor(base_cfg(), ExecutorKind::Parallel, 0));
    assert_identical(&serial, &parallel, "clean run");
}

#[test]
fn thread_count_does_not_change_results() {
    let one = run(with_executor(base_cfg(), ExecutorKind::Parallel, 1));
    let two = run(with_executor(base_cfg(), ExecutorKind::Parallel, 2));
    let many = run(with_executor(base_cfg(), ExecutorKind::Parallel, 7));
    assert_identical(&one, &two, "1 vs 2 threads");
    assert_identical(&one, &many, "1 vs 7 threads");
}

#[test]
fn dropout_counting_matches_across_executors() {
    let mut cfg = base_cfg();
    cfg.dropout = 0.5;
    cfg.rounds = 5;
    let serial = run(with_executor(cfg.clone(), ExecutorKind::Serial, 0));
    let parallel = run(with_executor(cfg, ExecutorKind::Parallel, 0));
    assert!(serial.dropped > 0, "injection never fired at dropout=0.5");
    assert_identical(&serial, &parallel, "dropout run");
}

#[test]
fn zero_survivor_rounds_behave_identically() {
    // Dropout so high that whole rounds are lost with near-certainty:
    // 20 Bernoulli(0.97) survival failures per run. Both executors must
    // count the same drops, move the same bytes (downloads still
    // happen), and leave the global vector identical.
    let mut cfg = base_cfg();
    cfg.dropout = 0.97;
    cfg.rounds = 5;
    let serial = run(with_executor(cfg.clone(), ExecutorKind::Serial, 0));
    let parallel = run(with_executor(cfg, ExecutorKind::Parallel, 0));
    assert_identical(&serial, &parallel, "zero-survivor run");
    // With these odds at least one round lost every client; the run
    // still finishes and the ledger still has one bucket per round.
    assert_eq!(serial.per_round.len(), 5);
    assert!(serial.dropped >= 15, "only {} drops at 0.97", serial.dropped);
}

#[test]
fn executors_identical_under_quantized_codec() {
    // The codec round trip happens inside the per-client work; make
    // sure a lossy wire format stays order-independent too.
    let mut cfg = base_cfg();
    cfg.codec = flocora::compression::CodecKind::Affine(8);
    let serial = run(with_executor(cfg.clone(), ExecutorKind::Serial, 0));
    let parallel = run(with_executor(cfg, ExecutorKind::Parallel, 3));
    assert_identical(&serial, &parallel, "q8 run");
}
