//! Wire-mode keystone tests: the networked coordinator/client pair
//! must be **byte-identical** to the in-process simulator.
//!
//! Three layers:
//!
//! 1. frame-codec property/fuzz tests — every frame round-trips;
//!    truncation at every byte offset, bad magic/version, oversized
//!    length prefixes, unknown types, trailing bytes, malformed
//!    strings and bad bools all come back as typed errors, never
//!    panics or huge allocations;
//! 2. claim-table lease/expiry state-machine tests with injected
//!    timestamps;
//! 3. loopback runs — N client threads against an in-process
//!    `serve_on` over real TCP, wall-stripped `run_json` asserted
//!    byte-equal to `Simulation::run` of the same config, across
//!    aggregators × codecs, plus a killed-client round asserted
//!    byte-equal to the simulator's `drop_plan` injection.

use std::net::TcpListener;
use std::thread;

use flocora::config::FlConfig;
use flocora::coordinator::executor::ClientResult;
use flocora::coordinator::Simulation;
use flocora::metrics::{run_json, strip_wall, Recorder};
use flocora::runtime::Engine;
use flocora::transport::wire::{run_client_loop, serve_on, ClaimTable,
                               ClientOpts, ClientReport, Frame, ServeOpts,
                               HEADER_LEN, MAX_FRAME_LEN, WIRE_VERSION};

// --- 1. frame codec ---------------------------------------------------

fn sample_frames() -> Vec<Frame> {
    vec![
        Frame::Hello { config: "rounds = 3\nseed = 7\n".into() },
        Frame::Hello { config: String::new() },
        Frame::Register { lo: 0, hi: 7 },
        Frame::Claim { round: 2, cid: 5 },
        Frame::Plan { round: 2, cid: 5, sampled: true, cancelled: false },
        Frame::Plan { round: 0, cid: 0, sampled: false, cancelled: true },
        Frame::Download {
            round: 1,
            cid: 3,
            codec: "q4".into(),
            payload: vec![0, 1, 2, 254, 255],
        },
        Frame::Download {
            round: 0,
            cid: 0,
            codec: String::new(),
            payload: Vec::new(),
        },
        Frame::Upload {
            round: 9,
            cid: 1,
            weight: 16.0,
            mean_loss: 2.302,
            mean_acc: 0.125,
            codec: "sparse_ef:0.5".into(),
            payload: (0..=255).collect(),
        },
        Frame::Complete { round: 1, cid: 2, status: 2 },
        Frame::Heartbeat { round: 4, cid: 4 },
        Frame::Abort { reason: "lease expired".into() },
    ]
}

#[test]
fn every_frame_round_trips() {
    for frame in sample_frames() {
        let bytes = frame.encode();
        let back = Frame::decode(&bytes)
            .unwrap_or_else(|e| panic!("{} failed: {e}", frame.kind()));
        assert_eq!(back, frame);
    }
}

#[test]
fn truncation_at_every_offset_is_a_typed_error() {
    for frame in sample_frames() {
        let bytes = frame.encode();
        for len in 0..bytes.len() {
            let res = Frame::decode(&bytes[..len]);
            assert!(
                res.is_err(),
                "{} truncated to {len}/{} decoded",
                frame.kind(),
                bytes.len()
            );
        }
    }
}

#[test]
fn trailing_bytes_are_rejected() {
    for frame in sample_frames() {
        // Grow the *body* while keeping the length prefix honest-sized:
        // a frame followed by garbage must not silently decode.
        let mut bytes = frame.encode();
        bytes.push(0xAB);
        assert!(
            Frame::decode(&bytes).is_err(),
            "{} with a trailing byte decoded",
            frame.kind()
        );
    }
}

#[test]
fn bad_magic_and_version_are_rejected() {
    let good = Frame::Heartbeat { round: 0, cid: 0 }.encode();
    for byte in 0..2 {
        let mut bad = good.clone();
        bad[byte] ^= 0x01;
        assert!(Frame::decode(&bad).is_err(), "magic byte {byte}");
    }
    let mut bad = good.clone();
    bad[2] = WIRE_VERSION + 1;
    let err = Frame::decode(&bad).unwrap_err().to_string();
    assert!(err.contains("version"), "{err}");
}

#[test]
fn oversized_length_prefix_is_capped_before_allocation() {
    // An 8-byte header claiming a multi-GB body must fail on the cap
    // check, not attempt the allocation (the slice is only 8 bytes, but
    // the error must be the cap, proving the check precedes any use of
    // the length).
    let mut header = Frame::Heartbeat { round: 0, cid: 0 }.encode();
    header.truncate(HEADER_LEN);
    let huge = (MAX_FRAME_LEN as u32) + 1;
    header[4..8].copy_from_slice(&huge.to_le_bytes());
    let err = Frame::decode(&header).unwrap_err().to_string();
    assert!(err.contains("cap"), "{err}");
}

#[test]
fn unknown_frame_type_is_rejected() {
    let mut bytes = Frame::Heartbeat { round: 0, cid: 0 }.encode();
    bytes[3] = 42;
    let err = Frame::decode(&bytes).unwrap_err().to_string();
    assert!(err.contains("unknown wire frame type 42"), "{err}");
}

#[test]
fn bad_bool_and_bad_utf8_are_rejected() {
    // Plan's `sampled` byte set to 2 (body layout: round u64, cid u64,
    // sampled u8, cancelled u8).
    let mut plan = Frame::Plan {
        round: 1,
        cid: 2,
        sampled: true,
        cancelled: false,
    }
    .encode();
    plan[HEADER_LEN + 16] = 2;
    let err = Frame::decode(&plan).unwrap_err().to_string();
    assert!(err.contains("bool"), "{err}");

    // Hello body that is not UTF-8.
    let mut hello = Frame::Hello { config: "ab".into() }.encode();
    hello[HEADER_LEN] = 0xFF;
    hello[HEADER_LEN + 1] = 0xFE;
    let err = Frame::decode(&hello).unwrap_err().to_string();
    assert!(err.contains("UTF-8"), "{err}");
}

#[test]
fn upload_stats_cross_the_wire_bit_exactly() {
    // f64 stats travel as IEEE bits — even NaN payloads (a fully
    // dropped shard's mean loss) must survive bit-for-bit.
    let frame = Frame::Upload {
        round: 0,
        cid: 0,
        weight: 12.0,
        mean_loss: f64::NAN,
        mean_acc: f64::from_bits(0x3FF0_0000_0000_0001),
        codec: "fp32".into(),
        payload: vec![1, 2, 3],
    };
    let Frame::Upload { mean_loss, mean_acc, .. } =
        Frame::decode(&frame.encode()).unwrap()
    else {
        panic!("decoded to a different frame type");
    };
    assert_eq!(mean_loss.to_bits(), f64::NAN.to_bits());
    assert_eq!(mean_acc.to_bits(), 0x3FF0_0000_0000_0001);
}

// --- 2. claim table ---------------------------------------------------

#[test]
fn late_upload_after_lease_expiry_does_not_double_count() {
    let mut t = ClaimTable::new(0, &[2, 5], &[], 64, 100);
    t.claim(2, 0);
    t.claim(5, 0);
    // Client 2's lease runs out; its slot settles as a drop.
    assert_eq!(t.expire(150), 1);
    // The straggler's upload arrives anyway — refused: the drop stands.
    let late = ClientResult {
        cid: 2,
        down_bytes: 64,
        update: None,
        cancelled: false,
    };
    assert!(!t.settle(2, late));
    assert!(t.drop_claim(5));
    let res = t.into_results().unwrap();
    assert_eq!(res.len(), 2);
    assert!(res.iter().all(|r| r.update.is_none() && !r.cancelled));
}

#[test]
fn force_drop_settles_claimed_and_unclaimed_slots() {
    let mut t = ClaimTable::new(1, &[0, 1, 2], &[1], 8, 1_000);
    t.claim(0, 0);
    // Slot 2 was never claimed; slot 1 is a pre-settled cancellation.
    assert!(!t.complete());
    assert_eq!(t.force_drop(), 2);
    assert!(t.complete());
    let res = t.into_results().unwrap();
    assert!(res[1].cancelled);
    assert!(!res[0].cancelled && !res[2].cancelled);
}

#[test]
fn reading_out_an_incomplete_table_is_an_error() {
    let mut t = ClaimTable::new(0, &[3], &[], 8, 1_000);
    t.claim(3, 0);
    assert!(t.into_results().is_err());
}

// --- 3. loopback byte-identity ---------------------------------------

fn tiny_cfg(aggregator: &str, codec: &str) -> FlConfig {
    let mut cfg = FlConfig {
        tag: "micro8_lora_fc_r4".into(),
        num_clients: 6,
        clients_per_round: 3,
        rounds: 3,
        local_epochs: 1,
        samples_per_client: 12,
        test_samples: 24,
        seed: 77,
        ..FlConfig::default()
    };
    cfg.set("aggregator", aggregator).unwrap();
    cfg.set("codec", codec).unwrap();
    cfg
}

/// In-process reference: `Simulation::run`, exported exactly like
/// `flocora train --json`, wall-stripped.
fn sim_json(cfg: FlConfig) -> (String, u64) {
    let engine = Engine::synthetic();
    let mut sim = Simulation::new(&engine, cfg).unwrap();
    let mut rec = Recorder::new("train");
    let summary = sim.run(&mut rec).unwrap();
    let dropped = sim.dropped_clients;
    (strip_wall(&run_json(&rec, &summary, dropped)).to_string(), dropped)
}

/// Wire run: `serve_on` on a loopback listener plus one OS thread per
/// client process, each hosting an id range (and optionally killing
/// itself at a (round, cid) coordinate). Returns the wall-stripped
/// JSON, the dropped count, and the client reports.
fn wire_json(
    cfg: FlConfig,
    splits: &[(usize, usize)],
    kill_at: Option<(usize, usize)>,
) -> (String, u64, Vec<ClientReport>) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let engine = Engine::synthetic();
    let opts = ServeOpts::default();
    let mut rec = Recorder::new("train");
    let (served, reports) = thread::scope(|s| {
        let server =
            s.spawn(|| serve_on(listener, &engine, cfg, &opts, &mut rec));
        let clients: Vec<_> = splits
            .iter()
            .map(|&(lo, hi)| {
                let connect = addr.to_string();
                s.spawn(move || {
                    run_client_loop(&ClientOpts {
                        connect,
                        lo,
                        hi,
                        retries: 10,
                        backoff_ms: 25,
                        kill_at: kill_at
                            .filter(|&(_, c)| c >= lo && c <= hi),
                        artifacts: "synthetic".into(),
                    })
                })
            })
            .collect();
        let reports: Vec<ClientReport> = clients
            .into_iter()
            .map(|c| c.join().unwrap().unwrap())
            .collect();
        (server.join().unwrap().unwrap(), reports)
    });
    let (summary, dropped) = served;
    (
        strip_wall(&run_json(&rec, &summary, dropped)).to_string(),
        dropped,
        reports,
    )
}

#[test]
fn loopback_matches_in_process_across_aggregators_and_codecs() {
    for aggregator in ["fedavg", "svt", "exact"] {
        for codec in ["fp32", "q4", "sparse_ef:0.5"] {
            let (sim, sim_dropped) = sim_json(tiny_cfg(aggregator, codec));
            let (wire, wire_dropped, reports) =
                wire_json(tiny_cfg(aggregator, codec), &[(0, 2), (3, 5)],
                          None);
            assert_eq!(
                sim, wire,
                "wire run diverged from the simulator for \
                 {aggregator}/{codec}"
            );
            assert_eq!(sim_dropped, wire_dropped);
            // 3 sampled slots per round × 3 rounds, nobody dropped.
            let uploads: usize = reports.iter().map(|r| r.uploads).sum();
            assert_eq!(uploads, 9, "{aggregator}/{codec}");
        }
    }
}

#[test]
fn killed_client_matches_the_simulators_drop_plan() {
    // Every client is sampled every round (4 of 4), so the kill
    // coordinate is guaranteed to be a live slot.
    let mk = || {
        let mut cfg = FlConfig {
            tag: "micro8_lora_fc_r4".into(),
            num_clients: 4,
            clients_per_round: 4,
            rounds: 3,
            local_epochs: 1,
            samples_per_client: 12,
            test_samples: 24,
            seed: 91,
            ..FlConfig::default()
        };
        cfg.set("codec", "q8").unwrap();
        cfg
    };
    // Simulator side: planned drop of client 2 in round 1.
    let mut sim_cfg = mk();
    sim_cfg.set("drop_plan", "1:2").unwrap();
    let (sim, sim_dropped) = sim_json(sim_cfg);
    // Wire side: the process hosting client 2 hangs up after its
    // round-1 download, then reconnects.
    let (wire, wire_dropped, reports) =
        wire_json(mk(), &[(0, 1), (2, 3)], Some((1, 2)));
    assert_eq!(sim_dropped, 1);
    assert_eq!(wire_dropped, 1);
    assert_eq!(
        sim, wire,
        "a killed wire client must be byte-identical to drop_plan"
    );
    assert_eq!(reports.iter().filter(|r| r.killed).count(), 1);
    // 12 slots total, one lost to the kill.
    let uploads: usize = reports.iter().map(|r| r.uploads).sum();
    assert_eq!(uploads, 11);
}
