//! Codec-focused integration tests on *real model layouts* (the exact
//! segment tables the manifest ships) — sizes here are the numbers that
//! become Table III/IV columns, so they are pinned tightly.

use flocora::compression::affine::segment_encoded_size;
use flocora::compression::{AffineCodec, Codec, CodecKind, Fp32Codec};
use flocora::model::{build_spec, ModelCfg, ParamKind, Variant};
use flocora::util::rng::Rng;

fn spec(model: &str, variant: Variant, rank: usize) -> flocora::model::ParamSpec {
    build_spec(ModelCfg::by_name(model).unwrap(), variant, rank)
}

fn randv(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| 0.05 * rng.normal() as f32).collect()
}

#[test]
fn resnet8_r32_q8_message_matches_table3_maths() {
    // Table III: int8 TCC = 55.56 MB over 100 rounds => ~277.8 kB/msg.
    let s = spec("resnet8", Variant::LoraFc, 32);
    let v = randv(s.num_trainable(), 1);
    let msg = AffineCodec::new(8).encode(&v, &s.trainable).unwrap();
    let mb = 2.0 * 100.0 * msg.size_bytes() as f64 / 1e6;
    assert!((mb - 55.56).abs() / 55.56 < 0.06, "TCC {mb} MB vs paper 55.56");
}

#[test]
fn resnet8_r32_q4_q2_match_table3() {
    let s = spec("resnet8", Variant::LoraFc, 32);
    let v = randv(s.num_trainable(), 2);
    for (bits, paper_mb) in [(4u32, 30.15), (2, 17.44)] {
        let msg = AffineCodec::new(bits).encode(&v, &s.trainable).unwrap();
        let mb = 2.0 * 100.0 * msg.size_bytes() as f64 / 1e6;
        assert!((mb - paper_mb).abs() / paper_mb < 0.08,
                "int{bits}: {mb} vs {paper_mb}");
    }
}

#[test]
fn encoded_size_formula_matches_encoder_on_real_layouts() {
    for (model, variant, rank) in [("micro8", Variant::LoraFc, 4),
                                   ("resnet8", Variant::LoraFc, 32),
                                   ("resnet18", Variant::LoraFc, 16)] {
        let s = spec(model, variant, rank);
        let v = randv(s.num_trainable(), 3);
        for bits in [2u32, 4, 8] {
            let msg = AffineCodec::new(bits).encode(&v, &s.trainable).unwrap();
            let formula: usize = s
                .trainable
                .iter()
                .map(|seg| segment_encoded_size(seg, bits))
                .sum();
            assert_eq!(msg.size_bytes(), formula, "{model} bits {bits}");
        }
    }
}

#[test]
fn norm_layers_travel_in_fp32_exactly() {
    // Paper §IV: "Normalization layers are not quantized."
    let s = spec("micro8", Variant::LoraFc, 4);
    let v = randv(s.num_trainable(), 4);
    let c = AffineCodec::new(2); // harshest setting
    let out = c.decode(&c.encode(&v, &s.trainable).unwrap(), &s.trainable)
        .unwrap();
    for seg in &s.trainable {
        if matches!(seg.kind, ParamKind::NormW | ParamKind::NormB) {
            assert_eq!(&out[seg.offset..seg.offset + seg.numel],
                       &v[seg.offset..seg.offset + seg.numel], "{}", seg.name);
        }
    }
}

#[test]
fn per_channel_grouping_beats_per_tensor_on_scaled_rows() {
    // Construct a vector whose rows have wildly different scales; the
    // per-channel scheme must reconstruct far better than one global
    // scale would (sanity that grouping is actually per-row).
    let s = spec("micro8", Variant::LoraFc, 4);
    let mut rng = Rng::new(5);
    let mut v = vec![0.0f32; s.num_trainable()];
    for seg in &s.trainable {
        if let Some(rows) = seg.quant_rows {
            let cols = seg.numel / rows;
            for r in 0..rows {
                let row_scale = 10.0f32.powi((r % 5) as i32 - 2);
                for c in 0..cols {
                    v[seg.offset + r * cols + c] =
                        row_scale * rng.normal() as f32;
                }
            }
        }
    }
    let c = AffineCodec::new(8);
    let out = c.decode(&c.encode(&v, &s.trainable).unwrap(), &s.trainable)
        .unwrap();
    for seg in &s.trainable {
        if let Some(rows) = seg.quant_rows {
            let cols = seg.numel / rows;
            for r in 0..rows {
                let base = seg.offset + r * cols;
                let row = &v[base..base + cols];
                // True-range seeds (the codec no longer anchors the
                // row range at zero), so the bound is the tight one.
                let lo = row.iter().cloned().fold(f32::INFINITY, f32::min);
                let hi = row.iter().cloned()
                    .fold(f32::NEG_INFINITY, f32::max);
                let scale = ((hi - lo) / 255.0).max(1e-12);
                for i in 0..cols {
                    assert!((out[base + i] - row[i]).abs() <= scale * 0.51,
                            "{} row {r}", seg.name);
                }
            }
        }
    }
}

#[test]
fn compression_ratio_ladder_on_resnet18() {
    // The Table IV Q8 ladder: q8 message ~3.86x smaller than fp32 for
    // the same adapter vector (fp overhead on scales + norm layers keeps
    // it under the ideal 4x).
    let s = spec("resnet18", Variant::LoraFc, 32);
    let v = randv(s.num_trainable(), 6);
    let fp = Fp32Codec.encode(&v, &s.trainable).unwrap();
    let q8 = AffineCodec::new(8).encode(&v, &s.trainable).unwrap();
    let ratio = fp.size_bytes() as f64 / q8.size_bytes() as f64;
    assert!(ratio > 3.5 && ratio < 4.0, "{ratio}");
}

#[test]
fn codec_kind_labels_round_trip() {
    for kind in [CodecKind::Fp32, CodecKind::Affine(8), CodecKind::TopK(0.6),
                 CodecKind::ZeroFl(0.9, 0.2)] {
        let label = kind.label();
        let parsed = CodecKind::parse(&label).unwrap();
        // (TopK/ZeroFl float formatting must survive the round trip.)
        assert_eq!(parsed.label(), label);
    }
}
