//! Integration tests over the live three-layer stack: PJRT runtime +
//! coordinator + data + codecs, against the core artifact set.
//!
//! Requires `make artifacts`. Every test builds its own Engine (cheap:
//! each compiles only the artifacts it touches).

use flocora::compression::CodecKind;
use flocora::config::FlConfig;
use flocora::coordinator::Simulation;
use flocora::metrics::Recorder;
use flocora::runtime::{Batch, Engine};
use flocora::util::rng::Rng;

fn engine() -> std::rc::Rc<Engine> {
    // One Engine per test thread: executables compile once per artifact
    // per thread instead of once per test. Engine is Sync these days (a
    // process-global would work), but a per-thread instance keeps the
    // tests free of cross-thread contention on the compile-cache lock.
    thread_local! {
        static ENGINE: std::rc::Rc<Engine> = std::rc::Rc::new(
            Engine::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
                .expect("run `make artifacts` first"));
    }
    ENGINE.with(|e| e.clone())
}

fn rand_batch(spec: &flocora::runtime::SpecEntry, seed: u64) -> Batch {
    let px = spec.image_size * spec.image_size * 3;
    let mut rng = Rng::new(seed);
    Batch {
        x: (0..spec.batch_size * px).map(|_| rng.f32()).collect(),
        y: (0..spec.batch_size).map(|_| rng.below(10) as i32).collect(),
        mask: vec![1.0; spec.batch_size],
        n: spec.batch_size,
    }
}

#[test]
fn manifest_loads_and_validates() {
    let e = engine();
    assert!(e.manifest().specs.len() >= 10);
    assert!(e.manifest().specs.contains_key("micro8_lora_fc_r4"));
    assert_eq!(e.manifest().quant_oracles.len(), 3);
}

#[test]
fn init_is_deterministic_and_seed_sensitive() {
    let e = engine();
    let s = e.session("micro8_lora_fc_r4").unwrap();
    let (a, fa) = s.init(7).unwrap();
    let (b, fb) = s.init(7).unwrap();
    let (c, _) = s.init(8).unwrap();
    assert_eq!(a, b);
    assert_eq!(fa, fb);
    assert_ne!(a, c);
}

#[test]
fn lora_init_up_projections_are_zero() {
    // Round-0 invariant (paper §III): adapters start as exact no-ops.
    let e = engine();
    let s = e.session("micro8_lora_fc_r4").unwrap();
    let (tr, _) = s.init(3).unwrap();
    for seg in &s.spec.trainable_segments {
        if matches!(seg.kind, flocora::model::ParamKind::LoraA) {
            let sl = &tr[seg.offset..seg.offset + seg.numel];
            assert!(sl.iter().all(|&v| v == 0.0), "{} not zero", seg.name);
        }
    }
}

#[test]
fn train_step_descends_on_fixed_batch() {
    let e = engine();
    let s = e.session("micro8_lora_fc_r4").unwrap();
    let (mut p, f) = s.init(1).unwrap();
    let mut m = vec![0.0; p.len()];
    let batch = rand_batch(&s.spec, 2);
    let mut first = None;
    let mut last = 0.0;
    for i in 0..25 {
        let st = s
            .train_step(&mut p, &mut m, &f, &batch, 0.02, 16.0)
            .unwrap();
        if i == 0 {
            first = Some(st.loss);
        }
        last = st.loss;
        assert!(st.loss.is_finite());
    }
    assert!(last < first.unwrap() * 0.7, "{first:?} -> {last}");
}

#[test]
fn eval_counts_are_bounded_and_mask_aware() {
    let e = engine();
    let s = e.session("micro8_lora_fc_r4").unwrap();
    let (p, f) = s.init(1).unwrap();
    let mut batch = rand_batch(&s.spec, 3);
    let (loss, correct) = s.eval_step(&p, &f, &batch, 16.0).unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    assert!(correct >= 0.0 && correct <= s.spec.batch_size as f64);
    // Masking out everything => exactly zero loss and zero correct.
    batch.mask = vec![0.0; s.spec.batch_size];
    let (l0, c0) = s.eval_step(&p, &f, &batch, 16.0).unwrap();
    assert_eq!(l0, 0.0);
    assert_eq!(c0, 0.0);
}

#[test]
fn full_variant_has_empty_frozen_and_ignores_scale() {
    let e = engine();
    let s = e.session("micro8_full").unwrap();
    let (mut p, f) = s.init(5).unwrap();
    assert!(f.is_empty());
    let batch = rand_batch(&s.spec, 4);
    let mut m = vec![0.0; p.len()];
    let mut p2 = p.clone();
    let mut m2 = vec![0.0; p.len()];
    let a = s.train_step(&mut p, &mut m, &f, &batch, 0.01, 16.0).unwrap();
    let b = s
        .train_step(&mut p2, &mut m2, &f, &batch, 0.01, 512.0)
        .unwrap();
    assert_eq!(a.loss, b.loss);
    assert_eq!(p, p2);
}

#[test]
fn quant_parity_rust_codec_vs_pallas_hlo() {
    // The cross-layer contract: the rust wire codec and the L1 pallas
    // kernel implement the *same* quantizer.
    let e = engine();
    let mut rng = Rng::new(99);
    for &bits in &[2u32, 4, 8] {
        let oracle = &e.manifest().quant_oracles[&bits];
        let n = oracle.rows * oracle.cols;
        let w: Vec<f32> =
            (0..n).map(|_| 2.5 * rng.normal() as f32).collect();
        let (deq_hlo, scale_hlo, _zp) = e.quant_oracle(bits, &w).unwrap();
        let seg = flocora::model::Segment {
            name: "o".into(),
            shape: vec![oracle.rows, oracle.cols],
            numel: n,
            kind: flocora::model::ParamKind::Conv,
            offset: 0,
            quant_rows: Some(oracle.rows),
        };
        use flocora::compression::Codec;
        let codec = flocora::compression::AffineCodec::new(bits);
        let msg = codec.encode(&w, std::slice::from_ref(&seg)).unwrap();
        let deq = codec.decode(&msg, std::slice::from_ref(&seg)).unwrap();
        let max_scale =
            scale_hlo.iter().cloned().fold(0.0f32, f32::max).max(1e-6);
        let diff = flocora::tensor::max_abs_diff(&deq_hlo, &deq);
        // 1-ulp-of-scale agreement (XLA may fuse the division).
        assert!(diff <= max_scale * 1e-3 + 1e-6,
                "bits={bits} diff={diff} max_scale={max_scale}");
    }
}

#[test]
fn one_round_moves_global_and_counts_bytes() {
    let e = engine();
    let cfg = FlConfig {
        tag: "micro8_lora_fc_r4".into(),
        num_clients: 4,
        clients_per_round: 2,
        rounds: 1,
        local_epochs: 1,
        samples_per_client: 16,
        test_samples: 40,
        ..FlConfig::default()
    };
    let mut sim = Simulation::new(&e, cfg).unwrap();
    let before = sim.global.clone();
    let frozen_before = sim.frozen.clone();
    sim.round().unwrap();
    assert_ne!(sim.global, before, "global vector must move");
    assert_eq!(sim.frozen, frozen_before, "W_initial must never move");
    // 2 clients x (down + up) fp32 messages of P params.
    let p_bytes = (sim.global.len() * 4) as u64;
    assert_eq!(sim.ledger.total_bytes(), 4 * p_bytes);
    assert_eq!(sim.ledger.up_msgs, 2);
    assert_eq!(sim.ledger.down_msgs, 2);
}

#[test]
fn quantized_run_is_cheaper_and_still_finite() {
    let e = engine();
    let mk = |codec| FlConfig {
        tag: "micro8_lora_fc_r4".into(),
        num_clients: 4,
        clients_per_round: 2,
        rounds: 2,
        local_epochs: 1,
        samples_per_client: 16,
        test_samples: 40,
        codec,
        seed: 11,
        ..FlConfig::default()
    };
    let mut fp = Simulation::new(&e, mk(CodecKind::Fp32)).unwrap();
    let mut q8 = Simulation::new(&e, mk(CodecKind::Affine(8))).unwrap();
    let mut rec_fp = Recorder::new("fp");
    let mut rec_q8 = Recorder::new("q8");
    let s_fp = fp.run(&mut rec_fp).unwrap();
    let s_q8 = q8.run(&mut rec_q8).unwrap();
    let ratio = s_fp.total_bytes as f64 / s_q8.total_bytes as f64;
    // micro8's adapter segments are tiny, so per-row scale/zp overhead
    // caps the ratio well under the ideal 4x (the ResNet-18 layout
    // reaches 3.9x — pinned in tests/codecs.rs).
    assert!(ratio > 2.0 && ratio < 4.1, "q8 ratio {ratio}");
    assert!(s_q8.final_acc.is_finite());
}

#[test]
fn deterministic_simulation_same_seed() {
    let e = engine();
    let cfg = FlConfig {
        tag: "micro8_lora_fc_r4".into(),
        num_clients: 4,
        clients_per_round: 2,
        rounds: 2,
        local_epochs: 1,
        samples_per_client: 16,
        test_samples: 40,
        ..FlConfig::default()
    };
    let run = |cfg: FlConfig| {
        let mut sim = Simulation::new(&e, cfg).unwrap();
        let mut rec = Recorder::new("d");
        sim.run(&mut rec).unwrap();
        (sim.global.clone(), rec.final_acc())
    };
    let (g1, a1) = run(cfg.clone());
    let (g2, a2) = run(cfg.clone());
    assert_eq!(g1, g2);
    assert_eq!(a1, a2);
    let mut cfg3 = cfg;
    cfg3.seed = 1234;
    let (g3, _) = run(cfg3);
    assert_ne!(g1, g3);
}

#[test]
fn aggregation_agnostic_same_loop_all_methods() {
    // The paper's §III claim, executed: four different methods flow
    // through the identical Simulation::round with only the codec (and
    // tag) changing.
    let e = engine();
    for (tag, codec) in [
        ("micro8_full", CodecKind::Fp32),
        ("micro8_lora_fc_r4", CodecKind::Affine(4)),
        ("micro8_full", CodecKind::TopK(0.3)),
        ("micro8_full", CodecKind::ZeroFl(0.9, 0.2)),
    ] {
        let cfg = FlConfig {
            tag: tag.into(),
            num_clients: 4,
            clients_per_round: 2,
            rounds: 1,
            local_epochs: 1,
            samples_per_client: 16,
            test_samples: 40,
            codec,
            ..FlConfig::default()
        };
        let mut sim = Simulation::new(&e, cfg).unwrap();
        let (loss, _) = sim.round().unwrap();
        assert!(loss.is_finite(), "{tag} {codec:?}");
    }
}

#[test]
fn sparse_codec_shrinks_messages_in_flight() {
    let e = engine();
    let cfg = FlConfig {
        tag: "micro8_full".into(),
        num_clients: 4,
        clients_per_round: 2,
        rounds: 1,
        local_epochs: 1,
        samples_per_client: 16,
        test_samples: 40,
        codec: CodecKind::TopK(0.2),
        ..FlConfig::default()
    };
    let mut sim = Simulation::new(&e, cfg).unwrap();
    sim.round().unwrap();
    let dense_bytes = (sim.global.len() * 4) as f64;
    let mean_up = sim.ledger.mean_up_msg();
    assert!(mean_up < dense_bytes * 0.35, "{mean_up} vs {dense_bytes}");
}

#[test]
fn table2_variants_all_load_and_step() {
    // All four ablation rows of Table II exist as artifacts and run.
    let e = engine();
    for tag in ["micro8_full", "micro8_lora_all_r4", "micro8_lora_norm_r4",
                "micro8_lora_fc_r4"] {
        let s = e.session(tag).unwrap();
        let (mut p, f) = s.init(1).unwrap();
        let mut m = vec![0.0; p.len()];
        let batch = rand_batch(&s.spec, 1);
        let st = s.train_step(&mut p, &mut m, &f, &batch, 0.01, 16.0).unwrap();
        assert!(st.loss.is_finite(), "{tag}");
    }
}

#[test]
fn dropout_failure_injection_survives() {
    // Heavy failure injection: most sampled clients crash before
    // uploading; the federation must keep making progress with the
    // survivors and never corrupt state when a whole round is lost.
    let e = engine();
    let cfg = FlConfig {
        tag: "micro8_lora_fc_r4".into(),
        num_clients: 6,
        clients_per_round: 3,
        rounds: 6,
        local_epochs: 1,
        samples_per_client: 16,
        test_samples: 40,
        dropout: 0.7,
        seed: 5,
        ..FlConfig::default()
    };
    let mut sim = Simulation::new(&e, cfg).unwrap();
    let mut rec = Recorder::new("dropout");
    let summary = sim.run(&mut rec).unwrap();
    assert!(sim.dropped_clients > 0, "injection never fired");
    assert!(summary.final_acc.is_finite());
    assert!(sim.global.iter().all(|v| v.is_finite()));
    // Downloads happened for every sampled client (they fail only at
    // upload), uploads only for survivors.
    assert!(sim.ledger.up_msgs < sim.ledger.down_msgs);
}

#[test]
fn lr_decay_changes_trajectory_but_stays_stable() {
    let e = engine();
    let mk = |decay: f32| FlConfig {
        tag: "micro8_lora_fc_r4".into(),
        num_clients: 4,
        clients_per_round: 2,
        rounds: 3,
        local_epochs: 1,
        samples_per_client: 16,
        test_samples: 40,
        lr_decay: decay,
        seed: 9,
        ..FlConfig::default()
    };
    let run = |decay: f32| {
        let mut sim = Simulation::new(&e, mk(decay)).unwrap();
        let mut rec = Recorder::new("d");
        sim.run(&mut rec).unwrap();
        sim.global.clone()
    };
    let constant = run(1.0);
    let decayed = run(0.5);
    assert_ne!(constant, decayed, "decay must alter the trajectory");
    assert!(decayed.iter().all(|v| v.is_finite()));
}
