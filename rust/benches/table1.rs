//! Bench target for **paper Table I**: ResNet-8 parameter counts across
//! the rank ladder. Fully analytic (the counts are architecture
//! arithmetic) — printed ours-vs-paper, plus a timing of the spec
//! builder itself for regression tracking.

use flocora::experiments::{paper, tables};
use flocora::model::{build_spec, ModelCfg, Variant};
use flocora::util::benchkit;

fn main() {
    print!("{}", tables::table1().render());
    println!();

    // Verify every row against the paper within 2%.
    let mut worst: f64 = 0.0;
    for &(rank, total_p, trained_p) in &paper::TABLE1[1..] {
        let spec = build_spec(ModelCfg::by_name("resnet8").unwrap(),
                              Variant::LoraFc, rank);
        let dt = (spec.num_total() as f64 - total_p).abs() / total_p;
        let dr = (spec.num_trainable() as f64 - trained_p).abs() / trained_p;
        worst = worst.max(dt).max(dr);
        assert!(dt < 0.02 && dr < 0.02, "r={rank} drifted from paper");
    }
    println!("max relative deviation from paper Table I: {:.2}%\n",
             worst * 100.0);

    println!("{}", benchkit::header());
    let st = benchkit::bench("build_spec(resnet8, lora_fc, r=32)", 10, 200,
                             || {
        let s = build_spec(ModelCfg::by_name("resnet8").unwrap(),
                           Variant::LoraFc, 32);
        std::hint::black_box(s.num_trainable());
    });
    println!("{}", st.row());
    println!("\ntable1 bench OK");
}
