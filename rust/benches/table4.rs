//! Bench target for **paper Table IV**: FLoCoRA (± int8) vs ZeroFL vs
//! magnitude pruning on the larger model.
//!
//! Message sizes / TCC are exact analytic reproductions on the real
//! ResNet-18 layout (printed vs paper). Accuracies are measured live at
//! the scaled profile with every method flowing through the identical
//! aggregation loop.

use flocora::compression::CodecKind;
use flocora::config::presets;
use flocora::experiments::{runners, tables};
use flocora::runtime::Engine;
use flocora::util::benchkit::env_usize;

fn main() {
    let (table, pairs) = tables::table4_sizes();
    print!("{}", table.render());
    // Headline: FLoCoRA r=16 FP is the paper's ÷18.6 row.
    let full = pairs[0].1;
    let r16 = pairs.iter().find(|(l, _)| l == "FLoCoRA r=16").unwrap().1;
    let ratio = full / r16;
    assert!((ratio - 18.6).abs() / 18.6 < 0.06,
            "headline ratio ÷{ratio:.1} vs paper ÷18.6");
    println!("headline reduction at r=16: ÷{ratio:.1} (paper ÷18.6)\n");

    // ---- scaled accuracy runs ------------------------------------------
    let rounds = env_usize("FLOCORA_BENCH_ROUNDS", 60);
    let nseeds = env_usize("FLOCORA_BENCH_SEEDS", 2);
    let seeds: Vec<u64> = (0..nseeds as u64).map(|i| 42 + i).collect();
    let engine = Engine::new("artifacts").expect("make artifacts");

    println!("scaled accuracy (micro8, {rounds} rounds, LDA 1.0 as in \
              Table IV):");
    println!("{:<16} {:>16} {:>12}", "method", "acc (scaled)", "msg kB");
    let matrix: Vec<(&str, &str, usize, CodecKind)> = vec![
        ("FedAvg", "micro8_full", 0, CodecKind::Fp32),
        ("ZeroFL 90/0.2", "micro8_full", 0, CodecKind::ZeroFl(0.9, 0.2)),
        ("MagPrune 40%", "micro8_full", 0, CodecKind::TopK(0.6)),
        ("MagPrune 80%", "micro8_full", 0, CodecKind::TopK(0.2)),
        ("FLoCoRA r=8", "micro8_lora_fc_r8", 8, CodecKind::Fp32),
        ("FLoCoRA r=8 Q8", "micro8_lora_fc_r8", 8, CodecKind::Affine(8)),
    ];
    let mut results = Vec::new();
    for (label, tag, rank, codec) in matrix {
        let mut cfg = presets::scaled_micro(tag, rank, codec);
        cfg.rounds = rounds;
        cfg.samples_per_client = 64;
        cfg.lda_alpha = 1.0; // Table IV's easier distribution
        let sweep = runners::run_seeds(&engine, &cfg, label, &seeds)
            .expect("run failed");
        println!("{:<16} {:>16} {:>12.1}", label, runners::cell(&sweep),
                 sweep.mean_up_msg_bytes / 1e3);
        results.push((label, sweep.acc_mean, sweep.mean_up_msg_bytes));
    }

    // Shape assertions. At paper scale the Q8 ladder is the smallest
    // message outright (analytic table above, exact); at the micro
    // profile the adapters are so small that per-row scale/zp overhead
    // keeps Q8 above MagPrune-80%'s bitmap, so the live-run claim is the
    // paper's *trade-off* claim instead: among all compressed methods,
    // FLoCoRA Q8 reaches the best accuracy, and it beats every baseline
    // that ships a smaller-or-similar message by a wide margin.
    let get = |l: &str| results.iter().find(|(a, _, _)| *a == l).unwrap();
    let q8 = get("FLoCoRA r=8 Q8");
    // Q8 must beat every *sparse baseline* (in the paper, same-rank FP
    // rows can edge out Q8 — Table IV r=16: 82.33 vs 81.89 — so FLoCoRA
    // FP is not part of the dominance claim).
    for baseline in ["ZeroFL 90/0.2", "MagPrune 40%", "MagPrune 80%"] {
        let b = get(baseline);
        assert!(q8.1 > b.1,
                "FLoCoRA Q8 ({:.1}) must beat {baseline} ({:.1})", q8.1, b.1);
    }
    let prune80 = get("MagPrune 80%");
    assert!(q8.1 - prune80.1 > 10.0,
            "Q8 must dominate the similarly-sized MagPrune 80% baseline");
    println!("\ntable4 bench OK");
}
