//! Bench target for **paper Table II**: the layer-trainability ablation
//! (FedAvg / FLoCoRA Vanilla / + Norm layers / + Final FC), measured
//! live at the scaled profile. The paper's qualitative result — Vanilla
//! collapses, training norm layers helps, unfreezing the final FC
//! recovers to near-FedAvg — is asserted as orderings.

use flocora::compression::CodecKind;
use flocora::config::presets;
use flocora::experiments::{paper, runners};
use flocora::runtime::Engine;
use flocora::util::benchkit::env_usize;

fn main() {
    let rounds = env_usize("FLOCORA_BENCH_ROUNDS", 60);
    let nseeds = env_usize("FLOCORA_BENCH_SEEDS", 2);
    let seeds: Vec<u64> = (0..nseeds as u64).map(|i| 42 + i).collect();
    let engine = Engine::new("artifacts").expect("make artifacts");

    println!("Table II ablation (scaled: micro8, {rounds} rounds, \
              {nseeds} seeds | paper: ResNet-8, CIFAR-10 LDA 0.5)\n");
    println!("{:<18} {:>16} {:>18}", "variant", "acc (scaled)",
             "paper (CIFAR)");

    // Vanilla trains adapters only — the paper observed instability;
    // keep its lr identical (the collapse is the point).
    let matrix: Vec<(&str, &str, usize)> = vec![
        ("FedAvg", "micro8_full", 0),
        ("FLoCoRA Vanilla", "micro8_lora_all_r4", 4),
        ("+ Norm. layers", "micro8_lora_norm_r4", 4),
        ("+ Final FC", "micro8_lora_fc_r4", 4),
    ];
    let mut results = Vec::new();
    for (i, (label, tag, rank)) in matrix.into_iter().enumerate() {
        let mut cfg = presets::scaled_micro(tag, rank, CodecKind::Fp32);
        cfg.rounds = rounds;
        cfg.samples_per_client = 64;
        let sweep = runners::run_seeds(&engine, &cfg, label, &seeds)
            .expect("run failed");
        let (_, _, pm, ps) = paper::TABLE2[i];
        println!("{:<18} {:>16} {:>13.2} ± {:.2}", label,
                 runners::cell(&sweep), pm, ps);
        results.push((label, sweep.acc_mean));
    }

    let get = |l: &str| results.iter().find(|(a, _)| *a == l).unwrap().1;
    // The paper's ordering: FC-unfrozen ≈ FedAvg ≫ Vanilla; norm-trained
    // sits between Vanilla and full FLoCoRA.
    assert!(get("+ Final FC") > get("FLoCoRA Vanilla"),
            "+FC must beat Vanilla");
    assert!(get("+ Final FC") > get("+ Norm. layers"),
            "+FC must beat +Norm");
    assert!(get("FedAvg") > get("FLoCoRA Vanilla"),
            "FedAvg must beat Vanilla");
    println!("\ntable2 bench OK (ablation ordering matches paper)");
}
