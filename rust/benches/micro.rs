//! Micro-benchmarks for the L3 hot paths (DESIGN.md §7): wire codecs,
//! FedAvg accumulation, data generation, and the PJRT train-step
//! round trip. These are the numbers the §Perf log in EXPERIMENTS.md
//! tracks before/after optimization.
//!
//! The "kernels" section is the before/after harness for the chunked
//! hot-loop kernels (`flocora::kernels`): every kernel is timed against
//! its retained scalar `_ref` twin on paper-scale geometry, and with
//! `FLOCORA_BENCH_JSON=<path>` the run emits the `BENCH_hotpaths.json`
//! trajectory file (ns/elem + speedup per kernel, round wall-time per
//! preset) that the CI `perf-smoke` job uploads and ratio-gates.

use flocora::compression::{AffineCodec, Codec, Fp32Codec, TopKCodec,
                           ZeroFlCodec};
use flocora::config::FlConfig;
use flocora::coordinator::aggregator::FedAvg;
use flocora::coordinator::{ExecutorKind, Simulation};
use flocora::data::{gen_image, lda_partition};
use flocora::kernels;
use flocora::model::{build_spec, ModelCfg, Variant};
use flocora::runtime::{Batch, Engine};
use flocora::tensor;
use flocora::transport::{simulate_round, ClientLoad, ClientProfiles,
                         NetworkModel, RoundLoad, SimParams};
use flocora::util::benchkit::{bench, env_usize, header, BenchStats};
use flocora::util::json::{self, Json};
use flocora::util::rng::Rng;

/// One before/after row: the scalar reference vs the chunked kernel on
/// the same data, printed as a table line and returned as the JSON
/// entry `BENCH_hotpaths.json` pins (ns/elem both ways + the ratio).
fn kernel_row(name: &str, geometry: &str, n: usize, scalar: &BenchStats,
              kernel: &BenchStats) -> Json {
    let sn = scalar.mean_s * 1e9 / n as f64;
    let kn = kernel.mean_s * 1e9 / n as f64;
    let speedup = scalar.mean_s / kernel.mean_s;
    println!("{name:<24} {n:>9} {sn:>14.3} {kn:>14.3} {speedup:>9.2}x");
    json::obj(vec![
        ("name", json::s(name)),
        ("geometry", json::s(geometry)),
        ("n", json::num(n as f64)),
        ("scalar_ns_per_elem", json::num(sn)),
        ("kernel_ns_per_elem", json::num(kn)),
        ("speedup", json::num(speedup)),
    ])
}

fn round_entry(preset: &str, mean_s: f64) -> Json {
    json::obj(vec![
        ("preset", json::s(preset)),
        ("mean_s", json::num(mean_s)),
    ])
}

fn main() {
    println!("{}", header());
    let mut kernel_entries: Vec<Json> = Vec::new();
    let mut round_entries: Vec<Json> = Vec::new();

    // ---- codecs on the real ResNet-8 r=32 adapter layout ---------------
    let spec = build_spec(ModelCfg::by_name("resnet8").unwrap(),
                          Variant::LoraFc, 32);
    let n = spec.num_trainable();
    let mut rng = Rng::new(1);
    let v: Vec<f32> = (0..n).map(|_| 0.05 * rng.normal() as f32).collect();

    let fp = Fp32Codec;
    let st = bench("fp32 encode (258K params)", 3, 50,
                   || { std::hint::black_box(
                        fp.encode(&v, &spec.trainable).unwrap()); });
    println!("{}   ({:.2} GB/s)", st.row(),
             (n * 4) as f64 / st.mean_s / 1e9);

    for bits in [8u32, 4, 2] {
        let c = AffineCodec::new(bits);
        let st = bench(&format!("affine q{bits} encode (258K params)"), 3, 30,
                       || { std::hint::black_box(
                            c.encode(&v, &spec.trainable).unwrap()); });
        println!("{}   ({:.0} Mparam/s)", st.row(),
                 n as f64 / st.mean_s / 1e6);
        let msg = c.encode(&v, &spec.trainable).unwrap();
        let st = bench(&format!("affine q{bits} decode"), 3, 30,
                       || { std::hint::black_box(
                            c.decode(&msg, &spec.trainable).unwrap()); });
        println!("{}", st.row());
    }

    let tk = TopKCodec::new(0.2);
    let st = bench("topk 20% encode (258K params)", 3, 30,
                   || { std::hint::black_box(tk.encode(&v, &[]).unwrap()); });
    println!("{}", st.row());
    let zf = ZeroFlCodec::new(0.9, 0.2);
    let st = bench("zerofl 0.9/0.2 encode (258K)", 3, 30,
                   || { std::hint::black_box(zf.encode(&v, &[]).unwrap()); });
    println!("{}", st.row());

    // ---- kernels: scalar reference vs 8-lane chunked --------------------
    // Paper-scale geometry: the ResNet-8 r=32 adapter vector (~258K
    // f32) for the element-wise loops, the ResNet-18 r=32→r=16 rank
    // projection for the row gather, 1000 concurrent flows for
    // water-filling. tests/properties.rs pins every pair bit-identical;
    // this section prices them and feeds BENCH_hotpaths.json.
    {
        println!();
        println!("{:<24} {:>9} {:>14} {:>14} {:>10}",
                 "kernel", "n", "scalar ns/el", "kernel ns/el", "speedup");
        let it = env_usize("FLOCORA_BENCH_KERNEL_ITERS", 40);
        let g8 = "resnet8 lora_fc r32 adapter";

        // Row-range scan (the affine encode min/max pass).
        let sr = bench("minmax_ref", 3, it,
                       || { std::hint::black_box(kernels::minmax_ref(&v)); });
        let kr = bench("minmax", 3, it,
                       || { std::hint::black_box(kernels::minmax(&v)); });
        kernel_entries.push(kernel_row("minmax", g8, n, &sr, &kr));

        // Quantize to q8 codes.
        let (lo, hi) = kernels::minmax(&v);
        let scale = ((hi - lo) / 255.0).max(1e-12);
        let mut codes_vec: Vec<u8> = Vec::with_capacity(n);
        let sr = bench("quant_ref", 3, it, || {
            codes_vec.clear();
            kernels::quant_codes_ref(&v, lo, scale, 255.0, &mut codes_vec);
            std::hint::black_box(codes_vec.len());
        });
        let mut codes = vec![0u8; n];
        let kr = bench("quant", 3, it, || {
            kernels::quant_codes(&v, lo, scale, 255.0, &mut codes);
            std::hint::black_box(codes[0]);
        });
        kernel_entries.push(kernel_row("quant_q8", g8, n, &sr, &kr));

        // Dequantize.
        let zp = -lo / scale;
        let mut dst = vec![0.0f32; n];
        let sr = bench("dequant_ref", 3, it, || {
            kernels::dequant_ref(&codes, scale, zp, &mut dst);
            std::hint::black_box(dst[0]);
        });
        let kr = bench("dequant", 3, it, || {
            kernels::dequant(&codes, scale, zp, &mut dst);
            std::hint::black_box(dst[0]);
        });
        kernel_entries.push(kernel_row("dequant_q8", g8, n, &sr, &kr));

        // Zero-copy merge fold: dequantize straight into the FedAvg
        // accumulator vs materialize-then-add (the pre-kernel path).
        let mut acc = vec![0.0f32; n];
        let sr = bench("decode_then_add", 3, it, || {
            kernels::dequant_ref(&codes, scale, zp, &mut dst);
            kernels::axpy_ref(&mut acc, &dst, 0.125);
            std::hint::black_box(acc[0]);
        });
        let kr = bench("dequant_axpy", 3, it, || {
            kernels::dequant_axpy(&codes, scale, zp, 0.125, &mut acc);
            std::hint::black_box(acc[0]);
        });
        kernel_entries.push(kernel_row("dequant_axpy", g8, n, &sr, &kr));

        // FedAvg weighted fold.
        let sr = bench("axpy_ref", 3, it, || {
            kernels::axpy_ref(&mut acc, &v, 0.125);
            std::hint::black_box(acc[0]);
        });
        let kr = bench("axpy", 3, it, || {
            kernels::axpy(&mut acc, &v, 0.125);
            std::hint::black_box(acc[0]);
        });
        kernel_entries.push(kernel_row("axpy", g8, n, &sr, &kr));

        // fp32 wire fold: little-endian payload straight into the
        // accumulator vs decoding a temporary f32 vector first.
        let bytes: Vec<u8> =
            v.iter().flat_map(|x| x.to_le_bytes()).collect();
        let sr = bench("le_decode_then_add", 3, it, || {
            let tmp: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            kernels::axpy_ref(&mut acc, &tmp, 0.125);
            std::hint::black_box(acc[0]);
        });
        let kr = bench("axpy_from_le", 3, it, || {
            kernels::axpy_from_le(&bytes, 0.125, &mut acc);
            std::hint::black_box(acc[0]);
        });
        kernel_entries.push(kernel_row("axpy_from_le", g8, n, &sr, &kr));

        // q4 bit-packing, both directions.
        let codes4: Vec<u8> = codes.iter().map(|c| c >> 4).collect();
        let mut packed = vec![0u8; kernels::packed_len(n, 4)];
        let sr = bench("pack_ref q4", 3, it, || {
            kernels::pack_ref(&codes4, 4, &mut packed);
            std::hint::black_box(packed[0]);
        });
        let kr = bench("pack q4", 3, it, || {
            kernels::pack_into(&codes4, 4, &mut packed);
            std::hint::black_box(packed[0]);
        });
        kernel_entries.push(kernel_row("pack_q4", g8, n, &sr, &kr));

        let mut unpacked = vec![0u8; n];
        let sr = bench("unpack_ref q4", 3, it, || {
            kernels::unpack_ref(&packed, 4, &mut unpacked);
            std::hint::black_box(unpacked[0]);
        });
        let kr = bench("unpack q4", 3, it, || {
            kernels::unpack_into(&packed, 4, &mut unpacked);
            std::hint::black_box(unpacked[0]);
        });
        kernel_entries.push(kernel_row("unpack_q4", g8, n, &sr, &kr));

        // Top-k magnitude selection (20% sparse upload).
        let k = n / 5;
        let tk_it = env_usize("FLOCORA_BENCH_TOPK_ITERS", 20);
        let sr = bench("topk_ref", 2, tk_it, || {
            std::hint::black_box(kernels::topk_indices_ref(&v, k).len());
        });
        let kr = bench("topk", 2, tk_it, || {
            std::hint::black_box(kernels::topk_indices(&v, k).len());
        });
        kernel_entries.push(kernel_row("topk_20pct", g8, n, &sr, &kr));

        // Hetero rank projection: ResNet-18 r=32 server rows sliced
        // down to an r=16 client (the rank-minor gather).
        let s18 = build_spec(ModelCfg::by_name("resnet18").unwrap(),
                             Variant::LoraFc, 32);
        let outer = s18.num_trainable() / 32;
        let src: Vec<f32> = (0..outer * 32).map(|i| i as f32).collect();
        let mut proj = vec![0.0f32; outer * 16];
        let gn = outer * 16;
        let sr = bench("gather_rows_ref", 3, it, || {
            kernels::gather_rows_ref(&src, 32, &mut proj, 16, 16);
            std::hint::black_box(proj[0]);
        });
        let kr = bench("gather_rows", 3, it, || {
            kernels::gather_rows(&src, 32, &mut proj, 16, 16);
            std::hint::black_box(proj[0]);
        });
        kernel_entries.push(kernel_row("gather_rows_r32_to_r16",
                                       "resnet18 lora_fc r32->r16",
                                       gn, &sr, &kr));

        // Max-min water-filling over 1000 concurrent flows (the
        // per-event rate recompute in the network simulator).
        let mut wrng = Rng::new(11);
        let caps: Vec<f64> =
            (0..1000).map(|_| 0.0005 + 0.01 * wrng.f64()).collect();
        let mut rates = vec![0.0f64; 1000];
        let mut scratch: Vec<u32> = Vec::new();
        let sr = bench("waterfill_ref", 3, it, || {
            kernels::waterfill_ref(&caps, &mut rates);
            std::hint::black_box(rates[0]);
        });
        let kr = bench("waterfill", 3, it, || {
            kernels::waterfill(&caps, &mut rates, &mut scratch);
            std::hint::black_box(rates[0]);
        });
        kernel_entries.push(kernel_row("waterfill_1000", "1000 flows",
                                       1000, &sr, &kr));
        println!();
    }

    // ---- aggregation ----------------------------------------------------
    let st = bench("fedavg add (258K params)", 3, 100, || {
        let mut agg = FedAvg::new(n);
        agg.add(&v, 10.0).unwrap();
        std::hint::black_box(agg.contributions());
    });
    println!("{}   ({:.2} GB/s)", st.row(),
             (n * 4) as f64 / st.mean_s / 1e9);
    let st = bench("axpy_weighted (1.23M f32)", 3, 100, || {
        let mut acc = vec![0.0f32; 1_227_594];
        tensor::axpy_weighted(&mut acc, &vec![1.0f32; 1_227_594], 0.5);
        std::hint::black_box(acc[0]);
    });
    println!("{}", st.row());

    // ---- data substrate -------------------------------------------------
    let st = bench("cifar-s gen_image 32x32", 3, 200, || {
        let mut out = vec![0.0f32; 32 * 32 * 3];
        gen_image(3, 32, &mut Rng::new(7), &mut out);
        std::hint::black_box(out[0]);
    });
    println!("{}", st.row());
    let st = bench("lda_partition 16x64 @32px", 1, 5, || {
        std::hint::black_box(lda_partition(16, 64, 10, 32, 0.5, 3)
            .total_samples());
    });
    println!("{}", st.row());

    // ---- round-time models: closed forms vs event simulator -------------
    // A 1000-client synthetic round (tiered profiles, 700 kB FLoCoRA-
    // sized messages each way) priced by the closed estimators and by
    // the discrete-event simulator at two chunk granularities. The
    // simulator's own cost must stay visible in the perf trajectory:
    // it runs per round, so a regression here taxes every event-model
    // experiment.
    {
        let net = NetworkModel::edge_lte();
        let profiles = ClientProfiles::tiered(1000, 7);
        let loads: Vec<ClientLoad> = (0..1000)
            .map(|cid| {
                let (td, tc, tu) =
                    profiles.stage_times(&net, cid, 700_000, 700_000);
                ClientLoad {
                    cid,
                    td,
                    tc,
                    tu,
                    down_bytes: 700_000,
                    up_bytes: 700_000,
                    waited: true,
                }
            })
            .collect();
        let st = bench("closed estimators, 1000 clients", 3, 200, || {
            let mut acc = RoundLoad::new();
            for l in &loads {
                acc.add_stages(l.td, l.tc, l.tu, l.down_bytes, l.up_bytes);
            }
            std::hint::black_box(
                (acc.serial_s(), acc.parallel_s(&net), acc.pipelined_s(&net)),
            );
        });
        println!("{}", st.row());
        let closed_mean = st.mean_s;
        for (key, label, params) in [
            ("event_sim_1000c_256kb",
             "event sim, 1000 clients, 256 kB chunks",
             SimParams { chunk_kb: 256, stage_queue: 4 }),
            ("event_sim_1000c_64kb",
             "event sim, 1000 clients, 64 kB chunks",
             SimParams { chunk_kb: 64, stage_queue: 4 }),
        ] {
            let st = bench(label, 2, 10, || {
                std::hint::black_box(
                    simulate_round(&net, &loads, &params).round_s,
                );
            });
            println!("{}   ({:.0}x closed forms)", st.row(),
                     st.mean_s / closed_mean);
            round_entries.push(round_entry(key, st.mean_s));
        }
    }

    // ---- PJRT train-step round trip (the L2/L1 hot path) ----------------
    // Falls back to the artifact-free synthetic engine when artifacts/
    // is absent (CI perf-smoke runs without PJRT artifacts); the rows
    // then price the surrogate, which is what the FL-round presets
    // below exercise anyway.
    let engine = Engine::new("artifacts").unwrap_or_else(|_| {
        println!("(artifacts/ unavailable — synthetic engine fallback)");
        Engine::synthetic()
    });
    for tag in ["micro8_lora_fc_r4", "micro8_full", "tiny8_lora_fc_r8"] {
        let session = engine.session(tag).expect("session");
        let s = &session.spec;
        let (mut p, f) = session.init(1).unwrap();
        let mut m = vec![0.0f32; p.len()];
        let px = s.image_size * s.image_size * 3;
        let mut rng = Rng::new(2);
        let batch = Batch {
            x: (0..s.batch_size * px).map(|_| rng.f32()).collect(),
            y: (0..s.batch_size).map(|_| rng.below(10) as i32).collect(),
            mask: vec![1.0; s.batch_size],
            n: s.batch_size,
        };
        let iters = env_usize("FLOCORA_BENCH_STEP_ITERS", 15);
        let st = bench(&format!("pjrt train_step {tag}"), 2, iters, || {
            session.train_step(&mut p, &mut m, &f, &batch, 0.01, 16.0)
                .unwrap();
        });
        println!("{}   ({:.1} img/s)", st.row(),
                 s.batch_size as f64 / st.mean_s);
        let st = bench(&format!("pjrt eval_step {tag}"), 2, iters, || {
            session.eval_step(&p, &f, &batch, 16.0).unwrap();
        });
        println!("{}", st.row());
    }

    // ---- round engine: serial vs parallel client execution -------------
    // Same seed => bit-identical trajectories; only wall-clock differs.
    // The parallel row should win clearly at 8 clients/round on any
    // multi-core box (acceptance bar for the executor refactor).
    let mk = |executor| FlConfig {
        tag: "micro8_lora_fc_r4".into(),
        num_clients: 16,
        clients_per_round: 8,
        local_epochs: 1,
        samples_per_client: 32,
        test_samples: 40,
        executor,
        ..FlConfig::default()
    };
    let iters = env_usize("FLOCORA_BENCH_ROUND_ITERS", 8);
    let mut serial_mean = f64::NAN;
    for kind in [ExecutorKind::Serial, ExecutorKind::Parallel] {
        let mut sim = Simulation::new(&engine, mk(kind)).expect("sim");
        let st = bench(&format!("fl round, 8 clients, {}", kind.label()),
                       1, iters, || { sim.round().unwrap(); });
        match kind {
            ExecutorKind::Serial => {
                serial_mean = st.mean_s;
                println!("{}", st.row());
                round_entries.push(round_entry("fl_round_serial", st.mean_s));
            }
            ExecutorKind::Parallel => {
                println!("{}   ({:.2}x vs serial)", st.row(),
                         serial_mean / st.mean_s);
                round_entries
                    .push(round_entry("fl_round_parallel", st.mean_s));
            }
        }
    }
    // The streaming merge at a tight out-of-order window: same bits,
    // bounded buffering — the row shows what the memory cap costs.
    let mut sim = Simulation::new(
        &engine,
        FlConfig { window: 2, ..mk(ExecutorKind::Parallel) },
    ).expect("sim");
    let st = bench("fl round, 8 clients, window=2", 1, iters,
                   || { sim.round().unwrap(); });
    println!("{}   ({:.2}x vs serial)", st.row(), serial_mean / st.mean_s);
    round_entries.push(round_entry("fl_round_window2", st.mean_s));

    // Straggler regime: tiered link/compute profiles + oversampled
    // sampling (K·(1+β) drawn, late clients cancelled before they
    // train). Cancellation skips real training work, so the row also
    // wins wall-clock, not just simulated wire time.
    let mut cfg = flocora::config::presets::by_name("straggler_micro")
        .expect("preset");
    cfg.local_epochs = 1;
    cfg.samples_per_client = 32;
    cfg.test_samples = 40;
    let mut sim = Simulation::new(&engine, cfg.clone()).expect("sim");
    let st = bench("fl round, straggler preset (oversample)", 1, iters,
                   || { sim.round().unwrap(); });
    println!("{}   ({} cancelled so far)", st.row(),
             sim.cancelled_clients);
    round_entries.push(round_entry("fl_round_straggler", st.mean_s));

    // Transfer overlap: same preset, codec work moved onto the
    // transport threads (`overlap = transfer`). Bits are identical to
    // the row above; the row shows what decoupling encode/decode from
    // the compute workers buys (or costs) in wall clock at this scale.
    cfg.overlap = flocora::transport::OverlapKind::Transfer;
    let mut sim = Simulation::new(&engine, cfg).expect("sim");
    let st = bench("fl round, straggler preset (overlap=transfer)", 1,
                   iters, || { sim.round().unwrap(); });
    println!("{}", st.row());
    round_entries.push(round_entry("fl_round_straggler_overlap", st.mean_s));

    // ---- BENCH_hotpaths.json --------------------------------------------
    // Written when FLOCORA_BENCH_JSON names a path (the CI perf-smoke
    // job sets it). The committed copy at the repo root is the baseline
    // the CI ratio gate compares fresh runs against — speedup ratios,
    // not wall times, so shared-runner noise cancels out.
    if let Ok(path) = std::env::var("FLOCORA_BENCH_JSON") {
        let doc = json::obj(vec![
            ("schema", json::s("flocora-bench-hotpaths-v1")),
            ("kernels", json::arr(kernel_entries)),
            ("rounds", json::arr(round_entries)),
        ]);
        std::fs::write(&path, doc.to_string() + "\n")
            .expect("write FLOCORA_BENCH_JSON");
        println!("wrote {path}");
    }
    println!("\nmicro bench OK");
}
