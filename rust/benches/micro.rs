//! Micro-benchmarks for the L3 hot paths (DESIGN.md §7): wire codecs,
//! FedAvg accumulation, data generation, and the PJRT train-step
//! round trip. These are the numbers the §Perf log in EXPERIMENTS.md
//! tracks before/after optimization.

use flocora::compression::{AffineCodec, Codec, Fp32Codec, TopKCodec,
                           ZeroFlCodec};
use flocora::config::FlConfig;
use flocora::coordinator::aggregator::FedAvg;
use flocora::coordinator::{ExecutorKind, Simulation};
use flocora::data::{gen_image, lda_partition};
use flocora::model::{build_spec, ModelCfg, Variant};
use flocora::runtime::{Batch, Engine};
use flocora::tensor;
use flocora::transport::{simulate_round, ClientLoad, ClientProfiles,
                         NetworkModel, RoundLoad, SimParams};
use flocora::util::benchkit::{bench, env_usize, header};
use flocora::util::rng::Rng;

fn main() {
    println!("{}", header());

    // ---- codecs on the real ResNet-8 r=32 adapter layout ---------------
    let spec = build_spec(ModelCfg::by_name("resnet8").unwrap(),
                          Variant::LoraFc, 32);
    let n = spec.num_trainable();
    let mut rng = Rng::new(1);
    let v: Vec<f32> = (0..n).map(|_| 0.05 * rng.normal() as f32).collect();

    let fp = Fp32Codec;
    let st = bench("fp32 encode (258K params)", 3, 50,
                   || { std::hint::black_box(
                        fp.encode(&v, &spec.trainable).unwrap()); });
    println!("{}   ({:.2} GB/s)", st.row(),
             (n * 4) as f64 / st.mean_s / 1e9);

    for bits in [8u32, 4, 2] {
        let c = AffineCodec::new(bits);
        let st = bench(&format!("affine q{bits} encode (258K params)"), 3, 30,
                       || { std::hint::black_box(
                            c.encode(&v, &spec.trainable).unwrap()); });
        println!("{}   ({:.0} Mparam/s)", st.row(),
                 n as f64 / st.mean_s / 1e6);
        let msg = c.encode(&v, &spec.trainable).unwrap();
        let st = bench(&format!("affine q{bits} decode"), 3, 30,
                       || { std::hint::black_box(
                            c.decode(&msg, &spec.trainable).unwrap()); });
        println!("{}", st.row());
    }

    let tk = TopKCodec::new(0.2);
    let st = bench("topk 20% encode (258K params)", 3, 30,
                   || { std::hint::black_box(tk.encode(&v, &[]).unwrap()); });
    println!("{}", st.row());
    let zf = ZeroFlCodec::new(0.9, 0.2);
    let st = bench("zerofl 0.9/0.2 encode (258K)", 3, 30,
                   || { std::hint::black_box(zf.encode(&v, &[]).unwrap()); });
    println!("{}", st.row());

    // ---- aggregation ----------------------------------------------------
    let st = bench("fedavg add (258K params)", 3, 100, || {
        let mut agg = FedAvg::new(n);
        agg.add(&v, 10.0).unwrap();
        std::hint::black_box(agg.contributions());
    });
    println!("{}   ({:.2} GB/s)", st.row(),
             (n * 4) as f64 / st.mean_s / 1e9);
    let st = bench("axpy_weighted (1.23M f32)", 3, 100, || {
        let mut acc = vec![0.0f32; 1_227_594];
        tensor::axpy_weighted(&mut acc, &vec![1.0f32; 1_227_594], 0.5);
        std::hint::black_box(acc[0]);
    });
    println!("{}", st.row());

    // ---- data substrate -------------------------------------------------
    let st = bench("cifar-s gen_image 32x32", 3, 200, || {
        let mut out = vec![0.0f32; 32 * 32 * 3];
        gen_image(3, 32, &mut Rng::new(7), &mut out);
        std::hint::black_box(out[0]);
    });
    println!("{}", st.row());
    let st = bench("lda_partition 16x64 @32px", 1, 5, || {
        std::hint::black_box(lda_partition(16, 64, 10, 32, 0.5, 3)
            .total_samples());
    });
    println!("{}", st.row());

    // ---- round-time models: closed forms vs event simulator -------------
    // A 1000-client synthetic round (tiered profiles, 700 kB FLoCoRA-
    // sized messages each way) priced by the closed estimators and by
    // the discrete-event simulator at two chunk granularities. The
    // simulator's own cost must stay visible in the perf trajectory:
    // it runs per round, so a regression here taxes every event-model
    // experiment.
    {
        let net = NetworkModel::edge_lte();
        let profiles = ClientProfiles::tiered(1000, 7);
        let loads: Vec<ClientLoad> = (0..1000)
            .map(|cid| {
                let (td, tc, tu) =
                    profiles.stage_times(&net, cid, 700_000, 700_000);
                ClientLoad {
                    cid,
                    td,
                    tc,
                    tu,
                    down_bytes: 700_000,
                    up_bytes: 700_000,
                    waited: true,
                }
            })
            .collect();
        let st = bench("closed estimators, 1000 clients", 3, 200, || {
            let mut acc = RoundLoad::new();
            for l in &loads {
                acc.add_stages(l.td, l.tc, l.tu, l.down_bytes, l.up_bytes);
            }
            std::hint::black_box(
                (acc.serial_s(), acc.parallel_s(&net), acc.pipelined_s(&net)),
            );
        });
        println!("{}", st.row());
        let closed_mean = st.mean_s;
        for (label, params) in [
            ("event sim, 1000 clients, 256 kB chunks",
             SimParams { chunk_kb: 256, stage_queue: 4 }),
            ("event sim, 1000 clients, 64 kB chunks",
             SimParams { chunk_kb: 64, stage_queue: 4 }),
        ] {
            let st = bench(label, 2, 10, || {
                std::hint::black_box(
                    simulate_round(&net, &loads, &params).round_s,
                );
            });
            println!("{}   ({:.0}x closed forms)", st.row(),
                     st.mean_s / closed_mean);
        }
    }

    // ---- PJRT train-step round trip (the L2/L1 hot path) ----------------
    let engine = Engine::new("artifacts").expect("make artifacts");
    for tag in ["micro8_lora_fc_r4", "micro8_full", "tiny8_lora_fc_r8"] {
        let session = engine.session(tag).expect("session");
        let s = &session.spec;
        let (mut p, f) = session.init(1).unwrap();
        let mut m = vec![0.0f32; p.len()];
        let px = s.image_size * s.image_size * 3;
        let mut rng = Rng::new(2);
        let batch = Batch {
            x: (0..s.batch_size * px).map(|_| rng.f32()).collect(),
            y: (0..s.batch_size).map(|_| rng.below(10) as i32).collect(),
            mask: vec![1.0; s.batch_size],
            n: s.batch_size,
        };
        let iters = env_usize("FLOCORA_BENCH_STEP_ITERS", 15);
        let st = bench(&format!("pjrt train_step {tag}"), 2, iters, || {
            session.train_step(&mut p, &mut m, &f, &batch, 0.01, 16.0)
                .unwrap();
        });
        println!("{}   ({:.1} img/s)", st.row(),
                 s.batch_size as f64 / st.mean_s);
        let st = bench(&format!("pjrt eval_step {tag}"), 2, iters, || {
            session.eval_step(&p, &f, &batch, 16.0).unwrap();
        });
        println!("{}", st.row());
    }

    // ---- round engine: serial vs parallel client execution -------------
    // Same seed => bit-identical trajectories; only wall-clock differs.
    // The parallel row should win clearly at 8 clients/round on any
    // multi-core box (acceptance bar for the executor refactor).
    let mk = |executor| FlConfig {
        tag: "micro8_lora_fc_r4".into(),
        num_clients: 16,
        clients_per_round: 8,
        local_epochs: 1,
        samples_per_client: 32,
        test_samples: 40,
        executor,
        ..FlConfig::default()
    };
    let iters = env_usize("FLOCORA_BENCH_ROUND_ITERS", 8);
    let mut serial_mean = f64::NAN;
    for kind in [ExecutorKind::Serial, ExecutorKind::Parallel] {
        let mut sim = Simulation::new(&engine, mk(kind)).expect("sim");
        let st = bench(&format!("fl round, 8 clients, {}", kind.label()),
                       1, iters, || { sim.round().unwrap(); });
        match kind {
            ExecutorKind::Serial => {
                serial_mean = st.mean_s;
                println!("{}", st.row());
            }
            ExecutorKind::Parallel => {
                println!("{}   ({:.2}x vs serial)", st.row(),
                         serial_mean / st.mean_s);
            }
        }
    }
    // The streaming merge at a tight out-of-order window: same bits,
    // bounded buffering — the row shows what the memory cap costs.
    let mut sim = Simulation::new(
        &engine,
        FlConfig { window: 2, ..mk(ExecutorKind::Parallel) },
    ).expect("sim");
    let st = bench("fl round, 8 clients, window=2", 1, iters,
                   || { sim.round().unwrap(); });
    println!("{}   ({:.2}x vs serial)", st.row(), serial_mean / st.mean_s);

    // Straggler regime: tiered link/compute profiles + oversampled
    // sampling (K·(1+β) drawn, late clients cancelled before they
    // train). Cancellation skips real training work, so the row also
    // wins wall-clock, not just simulated wire time.
    let mut cfg = flocora::config::presets::by_name("straggler_micro")
        .expect("preset");
    cfg.local_epochs = 1;
    cfg.samples_per_client = 32;
    cfg.test_samples = 40;
    let mut sim = Simulation::new(&engine, cfg.clone()).expect("sim");
    let st = bench("fl round, straggler preset (oversample)", 1, iters,
                   || { sim.round().unwrap(); });
    println!("{}   ({} cancelled so far)", st.row(),
             sim.cancelled_clients);

    // Transfer overlap: same preset, codec work moved onto the
    // transport threads (`overlap = transfer`). Bits are identical to
    // the row above; the row shows what decoupling encode/decode from
    // the compute workers buys (or costs) in wall clock at this scale.
    cfg.overlap = flocora::transport::OverlapKind::Transfer;
    let mut sim = Simulation::new(&engine, cfg).expect("sim");
    let st = bench("fl round, straggler preset (overlap=transfer)", 1,
                   iters, || { sim.round().unwrap(); });
    println!("{}", st.row());
    println!("\nmicro bench OK");
}
