//! Bench target for **paper Figure 2**: accuracy vs LoRA rank for
//! alpha = 2r and alpha = 16r, against the FedAvg reference line.
//!
//! The x-axis (trained parameters per rank) is exact; accuracies are
//! measured at the scaled profile. The paper's two claims are asserted:
//! (1) alpha = 16r dominates alpha = 2r on small CNNs from scratch,
//! (2) accuracy is non-decreasing in rank (up to run noise).

use flocora::compression::CodecKind;
use flocora::config::presets;
use flocora::experiments::{paper, runners, tables};
use flocora::runtime::Engine;
use flocora::util::benchkit::env_usize;

fn main() {
    println!("Fig. 2 x-axis (exact, ResNet-8 trained params):");
    for (r, p) in tables::fig2_param_axis() {
        println!("  r={r:<4} {:.1}K params", p as f64 / 1e3);
    }
    println!();

    let rounds = env_usize("FLOCORA_BENCH_ROUNDS", 48);
    let nseeds = env_usize("FLOCORA_BENCH_SEEDS", 2);
    let seeds: Vec<u64> = (0..nseeds as u64).map(|i| 42 + i).collect();
    let engine = Engine::new("artifacts").expect("make artifacts");

    // FedAvg reference line.
    let mut cfg = presets::scaled_micro("micro8_full", 0, CodecKind::Fp32);
    cfg.rounds = rounds;
    cfg.samples_per_client = 64;
    let fedavg = runners::run_seeds(&engine, &cfg, "fedavg", &seeds)
        .expect("fedavg run");
    println!("FedAvg reference: {} (paper: {:.2})\n",
             runners::cell(&fedavg), paper::FIG2_FEDAVG);

    println!("{:<6} {:>18} {:>18}", "rank", "alpha=2r", "alpha=16r");
    let ranks = [2usize, 4, 8, 16];
    let mut curve16 = Vec::new();
    let mut sum2 = 0.0;
    let mut sum16 = 0.0;
    for &r in &ranks {
        let tag = format!("micro8_lora_fc_r{r}");
        let mut row = Vec::new();
        for mult in [2.0f32, 16.0] {
            let mut cfg = presets::scaled_micro(&tag, r, CodecKind::Fp32);
            cfg.rounds = rounds;
            cfg.samples_per_client = 64;
            cfg.lora_alpha = mult * r as f32;
            let sweep = runners::run_seeds(
                &engine, &cfg, &format!("r{r}a{mult}"), &seeds)
                .expect("run failed");
            row.push(sweep.acc_mean);
        }
        println!("{:<6} {:>15.2} {:>18.2}", r, row[0], row[1]);
        sum2 += row[0];
        sum16 += row[1];
        curve16.push(row[1]);
    }

    // Claim (1): the 16r curve dominates on average.
    assert!(sum16 > sum2,
            "alpha=16r should dominate alpha=2r (paper Fig. 2): \
             {sum16:.1} vs {sum2:.1}");
    // Claim (2): the 16r curve trends upward: last >= first - noise.
    assert!(curve16.last().unwrap() >= &(curve16[0] - 5.0),
            "accuracy should not collapse with rank: {curve16:?}");
    println!("\nfig2 bench OK (alpha=16r dominates, rank trend holds)");
}
