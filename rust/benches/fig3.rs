//! Bench target for **paper Figure 3**: convergence behaviour of FedAvg,
//! FLoCoRA-FP and its 8/4/2-bit quantized variants. Emits one CSV per
//! curve (target/fig3_<label>.csv) and asserts the paper's qualitative
//! claims: int8 convergence is not delayed vs FP; int2 collapses.

use flocora::compression::CodecKind;
use flocora::config::presets;
use flocora::coordinator::Simulation;
use flocora::metrics::Recorder;
use flocora::runtime::Engine;
use flocora::util::benchkit::env_usize;

fn main() {
    let rounds = env_usize("FLOCORA_BENCH_ROUNDS", 60);
    let engine = Engine::new("artifacts").expect("make artifacts");

    let matrix: Vec<(&str, &str, usize, CodecKind)> = vec![
        ("fedavg", "micro8_full", 0, CodecKind::Fp32),
        ("flocora_fp", "micro8_lora_fc_r8", 8, CodecKind::Fp32),
        ("flocora_q8", "micro8_lora_fc_r8", 8, CodecKind::Affine(8)),
        ("flocora_q4", "micro8_lora_fc_r8", 8, CodecKind::Affine(4)),
        ("flocora_q2", "micro8_lora_fc_r8", 8, CodecKind::Affine(2)),
    ];

    println!("Fig. 3 convergence (micro8 scaled, {rounds} rounds):");
    let mut finals = Vec::new();
    let mut curves = Vec::new();
    for (label, tag, rank, codec) in matrix {
        let mut cfg = presets::scaled_micro(tag, rank, codec);
        cfg.rounds = rounds;
        cfg.samples_per_client = 64;
        cfg.eval_every = 4;
        let mut sim = Simulation::new(&engine, cfg).expect("sim");
        let mut rec = Recorder::new(label);
        let summary = sim.run(&mut rec).expect("run");
        std::fs::create_dir_all("target").ok();
        rec.write_csv(format!("target/fig3_{label}.csv")).expect("csv");
        let half = rec
            .rounds
            .iter()
            .find(|r| r.round * 2 >= rounds)
            .map(|r| r.test_acc)
            .unwrap_or(0.0);
        println!(
            "  {label:<12} mid-train acc {half:.3}  final {:.3}  \
             (target/fig3_{label}.csv)",
            summary.tail_acc
        );
        finals.push((label, summary.tail_acc));
        curves.push((label, half));
    }

    let f = |l: &str| finals.iter().find(|(a, _)| *a == l).unwrap().1;
    let h = |l: &str| curves.iter().find(|(a, _)| *a == l).unwrap().1;
    // int8 tracks FP (the paper's claim is one-sided: quantization must
    // not *delay* convergence — q8 being ahead of fp early, as happens
    // at small scales, is fine).
    assert!(h("flocora_q8") > h("flocora_fp") - 0.12,
            "q8 must not lag fp mid-training");
    // int2 collapses below everything else.
    assert!(f("flocora_q2") < f("flocora_fp"),
            "q2 must degrade vs fp");
    assert!(f("flocora_q2") < f("flocora_q8"),
            "q2 must degrade vs q8");
    println!("\nfig3 bench OK (q8 tracks fp; q2 collapses)");
}
