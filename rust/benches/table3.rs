//! Bench target for **paper Table III**: total communication cost for
//! FP / int8 / int4 / int2 FLoCoRA on ResNet-8 (r=32, 100 rounds).
//!
//! The TCC column is exact analytic arithmetic (printed vs the paper).
//! The accuracy column is measured live at the scaled profile
//! (DESIGN.md §2) with the real wire codecs in the loop:
//! set FLOCORA_BENCH_ROUNDS / FLOCORA_BENCH_SEEDS to rescale.

use flocora::compression::CodecKind;
use flocora::config::presets;
use flocora::experiments::{paper, runners, tables};
use flocora::runtime::Engine;
use flocora::util::benchkit::env_usize;

fn main() {
    let (table, pairs) = tables::table3();
    print!("{}", table.render());
    let fedavg = pairs[0].1;
    for (label, ratio) in [("FLoCoRA FP", 4.8), ("FLoCoRA int8", 17.7),
                           ("FLoCoRA int4", 32.6), ("FLoCoRA int2", 56.3)] {
        let ours = fedavg / pairs.iter().find(|(l, _)| l == label).unwrap().1;
        assert!((ours - ratio).abs() / ratio < 0.06,
                "{label} ratio ÷{ours:.1} vs paper ÷{ratio}");
    }
    println!("analytic ratios within 6% of paper\n");

    // ---- scaled accuracy runs (live stack) -----------------------------
    let rounds = env_usize("FLOCORA_BENCH_ROUNDS", 60);
    let nseeds = env_usize("FLOCORA_BENCH_SEEDS", 2);
    let seeds: Vec<u64> = (0..nseeds as u64).map(|i| 42 + i).collect();
    let engine = Engine::new("artifacts").expect("make artifacts");

    println!("scaled accuracy runs (micro8, {rounds} rounds, {nseeds} seeds) \
              — paper accuracies shown for shape comparison:");
    println!("{:<16} {:>16} {:>18}", "method", "acc (scaled)", "paper (CIFAR)");
    let matrix: Vec<(&str, &str, usize, CodecKind, f64, f64)> = vec![
        ("FedAvg FP", "micro8_full", 0, CodecKind::Fp32,
         paper::TABLE3[0].3, paper::TABLE3[0].4),
        ("FLoCoRA FP", "micro8_lora_fc_r8", 8, CodecKind::Fp32,
         paper::TABLE3[1].3, paper::TABLE3[1].4),
        ("FLoCoRA int8", "micro8_lora_fc_r8", 8, CodecKind::Affine(8),
         paper::TABLE3[2].3, paper::TABLE3[2].4),
        ("FLoCoRA int4", "micro8_lora_fc_r8", 8, CodecKind::Affine(4),
         paper::TABLE3[3].3, paper::TABLE3[3].4),
        ("FLoCoRA int2", "micro8_lora_fc_r8", 8, CodecKind::Affine(2),
         paper::TABLE3[4].3, paper::TABLE3[4].4),
    ];
    let mut results = Vec::new();
    for (label, tag, rank, codec, pm, ps) in matrix {
        let mut cfg = presets::scaled_micro(tag, rank, codec);
        cfg.rounds = rounds;
        cfg.samples_per_client = 64;
        let sweep = runners::run_seeds(&engine, &cfg, label, &seeds)
            .expect("run failed");
        println!("{:<16} {:>16} {:>13.2} ± {:.2}", label,
                 runners::cell(&sweep), pm, ps);
        results.push((label, sweep.acc_mean));
    }

    // Shape assertions (the paper's qualitative ordering):
    let get = |l: &str| results.iter().find(|(a, _)| *a == l).unwrap().1;
    assert!(get("FLoCoRA int8") > get("FLoCoRA int2"),
            "int8 must beat int2");
    assert!(get("FedAvg FP") > get("FLoCoRA int2"),
            "int2 must show real degradation");
    println!("\ntable3 bench OK (ordering matches paper shape)");
}
