//! Coordinator-scale benchmark: rounds/second as the registered
//! population grows from 10k to 1M clients, at 1 and at `scale_bench`'s
//! 8 aggregator shards.
//!
//! This is the tentpole number for the sharded coordinator: the lazy
//! federation keeps 1M registered clients at one fork seed each
//! (`data::partition::LAZY_THRESHOLD`), and the shard fan-out spreads
//! the 10k-sampled round's fold/ledger/stage work across threads
//! while staying bit-identical to `shards = 1`. Rows price the
//! synthetic engine (the same surrogate CI's sim-smoke pins), so the
//! trajectory tracks coordinator overhead, not PJRT throughput.
//!
//! With `FLOCORA_BENCH_JSON=<path>` the run emits the
//! `BENCH_scale.json` trajectory file the CI perf-smoke job uploads.
//! Knobs: `FLOCORA_BENCH_SCALE_ITERS` (timed rounds per row, default
//! 2) and `FLOCORA_BENCH_SCALE_MAX_REGISTERED` (skip larger rows —
//! skipped rows are printed, never silently dropped).

use flocora::config::presets;
use flocora::coordinator::Simulation;
use flocora::runtime::Engine;
use flocora::util::benchkit::{bench, env_usize, header};
use flocora::util::json::{self, Json};

fn row_entry(registered: usize, sampled: usize, shards: usize,
             rounds_per_s: f64) -> Json {
    json::obj(vec![
        ("registered", json::num(registered as f64)),
        ("sampled", json::num(sampled as f64)),
        ("shards", json::num(shards as f64)),
        ("rounds_per_s", json::num(rounds_per_s)),
    ])
}

fn main() {
    println!("{}", header());
    let iters = env_usize("FLOCORA_BENCH_SCALE_ITERS", 2);
    let cap = env_usize("FLOCORA_BENCH_SCALE_MAX_REGISTERED", usize::MAX);
    let engine = Engine::synthetic();
    let mut rows: Vec<Json> = Vec::new();

    println!("{:<12} {:>10} {:>7} {:>12}",
             "registered", "sampled", "shards", "rounds/s");
    for (registered, sampled) in
        [(10_000usize, 1_000usize), (100_000, 10_000), (1_000_000, 10_000)]
    {
        if registered > cap {
            println!("{registered:<12} (skipped: above \
                      FLOCORA_BENCH_SCALE_MAX_REGISTERED={cap})");
            continue;
        }
        for shards in [1usize, 8] {
            let mut cfg = presets::scale_bench();
            cfg.num_clients = registered;
            cfg.clients_per_round = sampled;
            cfg.shards = shards;
            let mut sim = Simulation::new(&engine, cfg).expect("sim");
            let st = bench(
                &format!("round {registered}reg {sampled}spl s={shards}"),
                1, iters, || { sim.round().unwrap(); });
            let rps = 1.0 / st.mean_s;
            println!("{registered:<12} {sampled:>10} {shards:>7} \
                      {rps:>12.3}");
            rows.push(row_entry(registered, sampled, shards, rps));
        }
    }

    // Written when FLOCORA_BENCH_JSON names a path (CI perf-smoke sets
    // it); the committed BENCH_scale.json at the repo root is the
    // baseline the trajectory is read against.
    if let Ok(path) = std::env::var("FLOCORA_BENCH_JSON") {
        let doc = json::obj(vec![
            ("schema", json::s("flocora-bench-scale-v1")),
            ("rows", json::arr(rows)),
        ]);
        std::fs::write(&path, doc.to_string() + "\n")
            .expect("write FLOCORA_BENCH_JSON");
        println!("wrote {path}");
    }
    println!("\nscale bench OK");
}
