//! `cargo xtask lint-determinism` — a custom static lint that keeps
//! the nondeterminism out of `rust/src`.
//!
//! The simulator's contract is that every preset/variant pair produces
//! bit-identical artifacts across runs, machines, and thread counts.
//! The compiler cannot check that, but most regressions arrive through
//! a handful of well-known doors. This lint bolts those doors shut:
//!
//! * `std-sync` — no direct `std::sync` / `std::thread` /
//!   `core::sync`: all concurrency must route through the
//!   `crate::sync` shim so the loom model checker sees it.
//! * `map-iter` — no `HashMap` / `HashSet` in coordinator or
//!   transport settle paths: their iteration order is nondeterministic.
//! * `wall-clock` — no `Instant` / `SystemTime` outside
//!   `util/benchkit.rs`, `main.rs`, the CLI command modules
//!   (`cli/`), and `transport/wire.rs`: simulated time comes from
//!   the transport model, never the host clock. (The wire module is
//!   the deliberate exception — real sockets lease claims and expire
//!   stragglers in real time; its exports are wall-stripped before
//!   any bit-identity comparison.)
//! * `rand-crate` — no ambient RNG anywhere: randomness flows from
//!   `Rng::for_client(seed, round, cid)` coordinates only.
//! * `kernel-ref` — every public fast-path kernel in
//!   `kernels/mod.rs` needs a `_ref` reference twin so tests can pin
//!   the optimized path bit-for-bit against scalar code.
//!
//! Escape hatch: `// det-lint: allow(<rule>) — <justification>` on the
//! offending line, or anywhere in the unbroken run of comment /
//! attribute lines immediately above it (a blank line breaks the run).
//! An allow with no justification, an allow naming an unknown rule,
//! and a stale allow that suppresses nothing are themselves
//! violations — escapes must stay explained and alive.
//!
//! Token-level by design: comments, strings, and char literals are
//! stripped first, then rules match whole tokens, so prose about
//! `std::sync` (like this paragraph) never trips the lint.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

const RULES: [&str; 5] =
    ["std-sync", "map-iter", "wall-clock", "rand-crate", "kernel-ref"];

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint-determinism") => {
            let src = args
                .iter()
                .position(|a| a == "--src")
                .and_then(|i| args.get(i + 1))
                .map(PathBuf::from)
                .unwrap_or_else(default_src);
            run_lint(&src)
        }
        _ => {
            eprintln!(
                "usage: cargo xtask lint-determinism [--src <dir>]"
            );
            ExitCode::from(2)
        }
    }
}

/// `rust/src`, located relative to this crate so the alias works from
/// any working directory.
fn default_src() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("xtask lives one level below the workspace root")
        .join("src")
}

fn run_lint(src: &Path) -> ExitCode {
    let mut files = Vec::new();
    if let Err(e) = collect_rs_files(src, &mut files) {
        eprintln!("lint-determinism: cannot walk {}: {e}", src.display());
        return ExitCode::from(2);
    }
    files.sort();

    let mut total = 0usize;
    for path in &files {
        let raw = match std::fs::read_to_string(path) {
            Ok(s) => s,
            Err(e) => {
                eprintln!(
                    "lint-determinism: cannot read {}: {e}",
                    path.display()
                );
                return ExitCode::from(2);
            }
        };
        let rel = path
            .strip_prefix(src)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        for v in analyze(&rel, &raw) {
            println!(
                "src/{rel}:{}: [{}] {}",
                v.line, v.rule, v.message
            );
            total += 1;
        }
    }

    if total > 0 {
        println!(
            "lint-determinism: {total} violation(s) across {} file(s)",
            files.len()
        );
        ExitCode::from(1)
    } else {
        println!(
            "lint-determinism: clean ({} file(s) scanned)",
            files.len()
        );
        ExitCode::SUCCESS
    }
}

fn collect_rs_files(
    dir: &Path,
    out: &mut Vec<PathBuf>,
) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

struct Violation {
    line: usize,
    rule: String,
    message: String,
}

struct Allow {
    line: usize,
    rule: String,
    justified: bool,
    used: bool,
}

/// Lint one file. `rel` is the path relative to `src/` with forward
/// slashes (rule scoping keys off it); `raw` is the file contents.
fn analyze(rel: &str, raw: &str) -> Vec<Violation> {
    let stripped = strip_code(raw);
    let raw_lines: Vec<&str> = raw.lines().collect();
    let code_lines: Vec<&str> = stripped.lines().collect();

    // A line that may carry or extend an allow run: comment or
    // attribute. Blank lines break the run.
    let comment_or_attr: Vec<bool> = raw_lines
        .iter()
        .map(|l| {
            let t = l.trim_start();
            t.starts_with("//") || t.starts_with("#[") || t.starts_with("#![")
        })
        .collect();

    let mut allows = parse_allows(&raw_lines);
    let mut violations = Vec::new();

    // --- token rules -------------------------------------------------
    let map_iter_scoped =
        rel.starts_with("coordinator/") || rel.starts_with("transport/");
    let wall_clock_exempt = rel == "util/benchkit.rs"
        || rel == "main.rs"
        || rel == "transport/wire.rs"
        || rel.starts_with("cli/");

    for (idx, line) in code_lines.iter().enumerate() {
        let lno = idx + 1;
        if has_path_token(line, "std::sync")
            || has_path_token(line, "std::thread")
            || has_path_token(line, "core::sync")
        {
            flag(
                &mut violations,
                &mut allows,
                &comment_or_attr,
                lno,
                "std-sync",
                "direct std::sync/std::thread use — route concurrency \
                 through the crate::sync shim so loom can model it",
            );
        }
        if map_iter_scoped
            && (has_ident(line, "HashMap") || has_ident(line, "HashSet"))
        {
            flag(
                &mut violations,
                &mut allows,
                &comment_or_attr,
                lno,
                "map-iter",
                "HashMap/HashSet in a coordinator/transport path — \
                 iteration order is nondeterministic; use \
                 BTreeMap/BTreeSet or a sorted Vec",
            );
        }
        if !wall_clock_exempt
            && (has_ident(line, "Instant") || has_ident(line, "SystemTime"))
        {
            flag(
                &mut violations,
                &mut allows,
                &comment_or_attr,
                lno,
                "wall-clock",
                "host clock outside util::benchkit / the CLI / the \
                 wire transport — simulated time must come from the \
                 transport model",
            );
        }
        if has_path_token(line, "rand::")
            || has_ident(line, "thread_rng")
            || has_ident(line, "fastrand")
            || has_ident(line, "getrandom")
        {
            flag(
                &mut violations,
                &mut allows,
                &comment_or_attr,
                lno,
                "rand-crate",
                "ambient RNG — all randomness must flow from \
                 util::rng::Rng::for_client coordinates",
            );
        }
    }

    // --- kernel-ref --------------------------------------------------
    if rel == "kernels/mod.rs" {
        let fns = public_fns(&code_lines);
        let names: Vec<&str> =
            fns.iter().map(|(_, n)| n.as_str()).collect();
        for (lno, name) in &fns {
            if name.ends_with("_ref") {
                continue;
            }
            let direct = format!("{name}_ref");
            let base = name.strip_suffix("_into").unwrap_or(name);
            let stripped_twin = format!("{base}_ref");
            if names.contains(&direct.as_str())
                || names.contains(&stripped_twin.as_str())
            {
                continue;
            }
            flag(
                &mut violations,
                &mut allows,
                &comment_or_attr,
                *lno,
                "kernel-ref",
                &format!(
                    "pub kernel `{name}` has no `{direct}` reference \
                     twin to pin bit-identity against"
                ),
            );
        }
    }

    // --- allow hygiene -----------------------------------------------
    for a in &allows {
        if !RULES.contains(&a.rule.as_str()) {
            violations.push(Violation {
                line: a.line,
                rule: "unknown-rule".into(),
                message: format!(
                    "det-lint allow names unknown rule `{}`",
                    a.rule
                ),
            });
        } else if !a.used {
            violations.push(Violation {
                line: a.line,
                rule: "stale-allow".into(),
                message: format!(
                    "det-lint allow({}) suppresses nothing — remove it",
                    a.rule
                ),
            });
        }
    }

    violations.sort_by_key(|v| v.line);
    violations
}

/// Record a violation at `lno` unless a live allow covers it; an allow
/// missing its justification is reported instead of honored (but still
/// counts as used, so it is not double-reported as stale).
fn flag(
    violations: &mut Vec<Violation>,
    allows: &mut [Allow],
    comment_or_attr: &[bool],
    lno: usize,
    rule: &str,
    message: &str,
) {
    match find_allow(allows, comment_or_attr, rule, lno) {
        Some(i) => {
            allows[i].used = true;
            if !allows[i].justified {
                violations.push(Violation {
                    line: allows[i].line,
                    rule: rule.into(),
                    message: format!(
                        "det-lint allow({rule}) has no justification — \
                         explain why the escape is sound"
                    ),
                });
            }
        }
        None => violations.push(Violation {
            line: lno,
            rule: rule.into(),
            message: message.into(),
        }),
    }
}

/// An allow covers line `lno` if it sits on `lno` itself or anywhere
/// in the unbroken run of comment/attribute lines immediately above it.
fn find_allow(
    allows: &[Allow],
    comment_or_attr: &[bool],
    rule: &str,
    lno: usize,
) -> Option<usize> {
    let mut candidate = lno;
    loop {
        if let Some(i) = allows
            .iter()
            .position(|a| a.line == candidate && a.rule == rule)
        {
            return Some(i);
        }
        if candidate <= 1 || !comment_or_attr[candidate - 2] {
            return None;
        }
        candidate -= 1;
    }
}

/// Scan raw lines for `det-lint: allow(<rule>)` markers. Justification
/// is whatever follows the closing paren, minus leading punctuation;
/// it must be substantive (>= 10 chars), not a bare dash.
fn parse_allows(raw_lines: &[&str]) -> Vec<Allow> {
    const MARK: &str = "det-lint: allow(";
    let mut out = Vec::new();
    for (idx, line) in raw_lines.iter().enumerate() {
        let Some(pos) = line.find(MARK) else { continue };
        let rest = &line[pos + MARK.len()..];
        let Some(close) = rest.find(')') else { continue };
        let rule = rest[..close].trim().to_string();
        let tail = rest[close + 1..]
            .trim_start_matches(|c: char| {
                c.is_whitespace() || "—–-:,.".contains(c)
            })
            .trim();
        out.push(Allow {
            line: idx + 1,
            rule,
            justified: tail.chars().count() >= 10,
            used: false,
        });
    }
    out
}

/// Lines whose (stripped) text declares a `pub fn`, with the name.
fn public_fns(code_lines: &[&str]) -> Vec<(usize, String)> {
    let mut out = Vec::new();
    for (idx, line) in code_lines.iter().enumerate() {
        let t = line.trim_start();
        let Some(rest) = t.strip_prefix("pub fn ") else { continue };
        let name: String = rest
            .chars()
            .take_while(|c| c.is_alphanumeric() || *c == '_')
            .collect();
        if !name.is_empty() {
            out.push((idx + 1, name));
        }
    }
    out
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Whole-identifier match: `Instant` must not match `Instantiate`.
fn has_ident(line: &str, ident: &str) -> bool {
    find_token(line, ident, true)
}

/// Path-prefix match: `std::sync` matches `std::sync::Mutex` but not
/// `mystd::sync`; `rand::` matches `rand::thread_rng` but not
/// `operand::x`.
fn has_path_token(line: &str, tok: &str) -> bool {
    find_token(line, tok, false)
}

fn find_token(line: &str, tok: &str, whole_ident: bool) -> bool {
    let bytes = line.as_bytes();
    let mut start = 0;
    while let Some(pos) = line[start..].find(tok) {
        let p = start + pos;
        let before_ok = p == 0 || !is_ident_byte(bytes[p - 1]);
        let end = p + tok.len();
        let after_ok = !whole_ident
            || end >= bytes.len()
            || !is_ident_byte(bytes[end]);
        if before_ok && after_ok {
            return true;
        }
        start = p + 1;
    }
    false
}

/// Replace comments, string/char-literal contents, and raw strings
/// with spaces, preserving every newline so line numbers survive.
fn strip_code(src: &str) -> String {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut out = String::with_capacity(src.len());
    let mut i = 0;
    let blank = |c: char| if c == '\n' { '\n' } else { ' ' };
    while i < n {
        let c = b[i];
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            while i < n && b[i] != '\n' {
                out.push(' ');
                i += 1;
            }
        } else if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let mut depth = 1usize;
            out.push_str("  ");
            i += 2;
            while i < n && depth > 0 {
                if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    out.push_str("  ");
                    i += 2;
                } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    out.push_str("  ");
                    i += 2;
                } else {
                    out.push(blank(b[i]));
                    i += 1;
                }
            }
        } else if c == 'r'
            && i + 1 < n
            && (b[i + 1] == '"' || b[i + 1] == '#')
            && (i == 0 || !is_ident_byte(b[i - 1] as u8))
        {
            // Raw string r"..." / r#"..."# (any hash count).
            let mut j = i + 1;
            let mut hashes = 0usize;
            while j < n && b[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if j < n && b[j] == '"' {
                out.push(' '); // the r
                for _ in 0..hashes {
                    out.push(' ');
                }
                out.push(' '); // the opening quote
                j += 1;
                'raw: while j < n {
                    if b[j] == '"' {
                        let mut k = 0usize;
                        while k < hashes && j + 1 + k < n && b[j + 1 + k] == '#'
                        {
                            k += 1;
                        }
                        if k == hashes {
                            for _ in 0..=hashes {
                                out.push(' ');
                            }
                            j += 1 + hashes;
                            break 'raw;
                        }
                    }
                    out.push(blank(b[j]));
                    j += 1;
                }
                i = j;
            } else {
                out.push(c);
                i += 1;
            }
        } else if c == '"' {
            out.push(' ');
            i += 1;
            while i < n {
                if b[i] == '\\' && i + 1 < n {
                    out.push(' ');
                    out.push(blank(b[i + 1]));
                    i += 2;
                } else if b[i] == '"' {
                    out.push(' ');
                    i += 1;
                    break;
                } else {
                    out.push(blank(b[i]));
                    i += 1;
                }
            }
        } else if c == '\'' {
            // Lifetime or char literal. `'a'` is a char; `'a,`/`'a>`
            // is a lifetime (next char identifier-ish, the one after
            // not a closing quote).
            let is_lifetime = i + 1 < n
                && (b[i + 1].is_alphabetic() || b[i + 1] == '_')
                && !(i + 2 < n && b[i + 2] == '\'');
            if is_lifetime {
                out.push(c);
                i += 1;
            } else {
                out.push(' ');
                i += 1;
                while i < n {
                    if b[i] == '\\' && i + 1 < n {
                        out.push(' ');
                        out.push(blank(b[i + 1]));
                        i += 2;
                    } else if b[i] == '\'' {
                        out.push(' ');
                        i += 1;
                        break;
                    } else if b[i] == '\n' {
                        // Not a char literal after all; bail out.
                        break;
                    } else {
                        out.push(' ');
                        i += 1;
                    }
                }
            }
        } else {
            out.push(c);
            i += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_hit(rel: &str, src: &str) -> Vec<String> {
        analyze(rel, src).into_iter().map(|v| v.rule).collect()
    }

    #[test]
    fn strip_removes_comments_strings_and_chars() {
        let src = "let a = \"std::sync\"; // std::sync\n\
                   let b = 'x'; /* HashMap */ let c: Vec<&'static str>;\n";
        let s = strip_code(src);
        assert!(!s.contains("std::sync"));
        assert!(!s.contains("HashMap"));
        assert!(s.contains("'static"), "lifetimes must survive: {s}");
        assert_eq!(s.lines().count(), src.lines().count());
    }

    #[test]
    fn strip_handles_raw_strings() {
        let s = strip_code("let re = r#\"Instant \"quoted\" \"#;\nInstant");
        assert_eq!(s.matches("Instant").count(), 1);
    }

    #[test]
    fn std_sync_fires_and_crate_sync_does_not() {
        assert_eq!(
            rules_hit("foo.rs", "use std::sync::Mutex;\n"),
            ["std-sync"]
        );
        assert!(rules_hit("foo.rs", "use crate::sync::Mutex;\n").is_empty());
        assert_eq!(
            rules_hit("foo.rs", "std::thread::spawn(|| ());\n"),
            ["std-sync"]
        );
    }

    #[test]
    fn map_iter_is_scoped_to_coordinator_and_transport() {
        let src = "use std::collections::HashMap;\n";
        assert_eq!(rules_hit("coordinator/server.rs", src), ["map-iter"]);
        assert_eq!(rules_hit("transport/sim.rs", src), ["map-iter"]);
        // The shard-merge path: its merge order is the bit-identity
        // contract, so the rule must keep covering it.
        assert_eq!(rules_hit("coordinator/shard.rs", src), ["map-iter"]);
        assert!(rules_hit("runtime/mod.rs", src).is_empty());
    }

    #[test]
    fn wall_clock_exempts_benchkit_and_cli() {
        let src = "let t = std::time::Instant::now();\n";
        assert_eq!(rules_hit("compression/lora.rs", src), ["wall-clock"]);
        assert!(rules_hit("util/benchkit.rs", src).is_empty());
        assert!(rules_hit("main.rs", src).is_empty());
        // "Instantiate" is a different identifier.
        assert!(rules_hit("foo.rs", "fn Instantiate() {}\n").is_empty());
    }

    #[test]
    fn wall_clock_exempts_wire_but_not_its_neighbours() {
        let src = "let deadline = Instant::now();\n";
        // The real-socket transport and the CLI command modules lease
        // and retry in genuine wall-clock time — exempt by path.
        assert!(rules_hit("transport/wire.rs", src).is_empty());
        assert!(rules_hit("cli/serve.rs", src).is_empty());
        assert!(rules_hit("cli/client.rs", src).is_empty());
        assert!(rules_hit("cli/mod.rs", src).is_empty());
        // The exemption must not leak into the simulated-transport or
        // coordinator paths next door.
        assert_eq!(rules_hit("transport/stage.rs", src), ["wall-clock"]);
        assert_eq!(rules_hit("transport/sim.rs", src), ["wall-clock"]);
        assert_eq!(
            rules_hit("coordinator/server.rs", src),
            ["wall-clock"]
        );
    }

    #[test]
    fn rand_crate_fires_everywhere() {
        assert_eq!(
            rules_hit("util/rng.rs", "let x = rand::random::<f32>();\n"),
            ["rand-crate"]
        );
        assert_eq!(
            rules_hit("foo.rs", "let r = thread_rng();\n"),
            ["rand-crate"]
        );
        // `operand::` must not match `rand::`.
        assert!(rules_hit("foo.rs", "use operand::x;\n").is_empty());
    }

    #[test]
    fn allow_on_same_line_and_in_comment_run_suppresses() {
        let same = "use std::sync::Mutex; \
                    // det-lint: allow(std-sync) — shim re-export only\n";
        assert!(rules_hit("sync.rs", same).is_empty());

        let run = "// det-lint: allow(std-sync) — shim re-export only\n\
                   // continuation of the explanation\n\
                   #[cfg(not(loom))]\n\
                   pub use std::sync::Mutex;\n";
        assert!(rules_hit("sync.rs", run).is_empty());

        // A blank line breaks the run: the allow goes stale and the
        // violation stands.
        let broken = "// det-lint: allow(std-sync) — shim re-export only\n\
                      \n\
                      pub use std::sync::Mutex;\n";
        let hits = rules_hit("sync.rs", broken);
        assert!(hits.contains(&"std-sync".to_string()), "{hits:?}");
        assert!(hits.contains(&"stale-allow".to_string()), "{hits:?}");
    }

    #[test]
    fn allow_without_justification_is_reported() {
        let src = "// det-lint: allow(std-sync)\n\
                   pub use std::sync::Mutex;\n";
        let v = analyze("sync.rs", src);
        assert_eq!(v.len(), 1);
        assert!(v[0].message.contains("justification"), "{}", v[0].message);
    }

    #[test]
    fn stale_and_unknown_allows_are_violations() {
        let src = "// det-lint: allow(wall-clock) — nothing here uses it\n\
                   // det-lint: allow(no-such-rule) — bogus\n\
                   fn quiet() {}\n";
        let mut hits = rules_hit("foo.rs", src);
        hits.sort();
        assert_eq!(hits, ["stale-allow", "unknown-rule"]);
    }

    #[test]
    fn kernel_ref_requires_a_reference_twin() {
        let ok = "pub fn axpy(a: &mut [f32]) {}\n\
                  pub fn axpy_ref(a: &mut [f32]) {}\n\
                  pub fn pack_into(o: &mut Vec<u8>) {}\n\
                  pub fn pack_ref(o: &mut Vec<u8>) {}\n";
        assert!(rules_hit("kernels/mod.rs", ok).is_empty());

        let missing = "pub fn fused_madd(a: &mut [f32]) {}\n";
        assert_eq!(rules_hit("kernels/mod.rs", missing), ["kernel-ref"]);
        // Outside kernels/mod.rs the rule does not apply.
        assert!(rules_hit("kernels/simd.rs", missing).is_empty());
    }

    #[test]
    fn kernel_ref_allow_rides_the_doc_comment_run() {
        let src = "/// Size arithmetic only; nothing to diverge.\n\
                   // det-lint: allow(kernel-ref) — pure size arithmetic, \
                   no float path to pin\n\
                   #[inline]\n\
                   pub fn packed_len(n: usize) -> usize { n }\n";
        assert!(rules_hit("kernels/mod.rs", src).is_empty());
    }
}
